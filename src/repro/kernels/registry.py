"""One kernel registry: declarative impls, one override ladder, one tuner.

LIKWID's API bet (the paper, §II) is a *small, stable, named* surface:
event groups and marker regions you can force from the environment,
instead of PAPI's per-counter sprawl.  Our kernel layer had drifted the
PAPI way — PR 3 and PR 4 each grew their own select/run/autotune trio,
``paged_decode`` rode the attention ladder as a pseudo-impl that
``run_attention`` had to explicitly reject, tuned winners lived in two
process-local dicts that died on restart, and three kernels sat outside
dispatch entirely.  This module is the redesign:

* **Declarative impls.**  Every implementation is a :class:`KernelSpec`
  (family, name, callable, static capability predicate, layout contract,
  oracle link, optional tune space) registered with
  :func:`register_impl` — adding a kernel family is a registration, not
  a new ladder.
* **One override ladder**, per family:  the :func:`use_impl` thread-local
  context, then ``REPRO_IMPL`` (``"attention=pallas_flash,
  paged_decode=pallas_paged"``), then the legacy ``REPRO_ATTN_IMPL``
  spelling (mapped onto the attention + paged_decode families so every
  existing workflow keeps working), then the family's heuristic.
  ``ServeConfig.impls`` pins through the same context, exactly like
  ``attn_impl`` always did.
* **One autotuner.**  :func:`autotune` reads each tuned spec's candidate
  generator + VMEM estimator, sweeps the probes through
  ``ProfileSession.measure`` (lower+compile cold, disk lookup warm,
  never executed), scores with the chip roofline, and records winners in
  a lock-guarded process table that :func:`best` serves to dispatch.
* **Disk-persistent winners.**  Sweep outcomes are ArtifactCache entries
  keyed like probes (family + tune key + toolchain, including the repo
  source fingerprint), so a fresh process warm-starts with **zero
  sweeps and zero lowerings**: ``autotune`` returns the persisted record
  without measuring, and ``best`` resolves tuned choices straight from
  disk even if ``autotune`` is never called.

Registered families (see :func:`describe` for the live table)::

    attention     pallas_flash | jnp_flash | full      tune: (bq, bk)
    paged_decode  pallas_paged | jnp_paged             tune: (page_size, ppb)
                  | pallas_paged_q8 | jnp_paged_q8     (int8 pages + scales)
    stream_triad  pallas_triad | xla_triad             tune: (block_rows,)
    jacobi7       wavefront | naive                    tune: (block_x,)
    ssd_scan      pallas_ssd | jnp_scan                tune: (chunk,)

``repro.kernels.legacy`` is the one deprecation shim over this module
(``dispatch``/``autotune`` re-export it); the migration table lives in
its docstring.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import os
import threading
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import hwinfo
from repro.core.artifact_cache import ArtifactCache, canonical_digest

__all__ = [
    "KernelSpec", "TuneSpace", "TuneRecord", "register_impl",
    "register_family", "families", "impls", "get_spec", "describe",
    "use_impl", "parse_impl_spec", "override_for", "select", "run",
    "autotune", "best", "record", "clear_tune_table", "tune_table",
    "dump_tune_table", "default_interpret", "LEGACY_ATTN_MAP",
    "use_mesh_facts", "mesh_facts", "mesh_key_tag",
]


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def default_interpret(backend: Optional[str] = None) -> bool:
    """Pallas interpret mode from backend detection (not a hardcoded True).

    ``REPRO_KERNEL_COMPILE=1`` forces compiled, ``=0`` forces interpret;
    otherwise TPU compiles and everything else interprets.
    """
    env = os.environ.get("REPRO_KERNEL_COMPILE")
    if env is not None:
        return env != "1"
    return (backend or jax.default_backend()) != "tpu"


def _pow2_up(n: int) -> int:
    """Round up to a power of two (>= 1)."""
    return 1 << max(int(n) - 1, 0).bit_length()


def _backend(backend: Optional[str]) -> str:
    return backend or jax.default_backend()


def _dtype_name(dtype) -> str:
    return jnp.dtype(dtype).name


# ---------------------------------------------------------------------------
# the data model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TuneSpace:
    """Declarative tune space for one (tunable) implementation.

    ``key(**facts)`` names the sweep (and, unless ``lookup_key`` is given,
    the record :func:`best` looks up); ``candidates(**facts)`` yields
    candidate tuples; ``vmem(cand, itemsize, **facts)`` estimates the
    kernel's VMEM working set so oversized candidates are gated before
    any XLA work; ``probe(cand, interpret, **facts)`` returns
    ``(module-level fn, abstract args)`` for ``ProfileSession.measure``
    (module-level so the fingerprint — the cache key — is stable across
    processes); ``record_keys(scores, **facts)`` optionally fans one
    sweep into several lookup records (the paged sweep records a winner
    per page_size); ``default`` is the untuned fallback choice (a tuple,
    or a callable over the lookup facts).
    """

    key: Callable[..., str]
    candidates: Callable[..., Sequence[Tuple]]
    vmem: Callable[..., int]
    probe: Callable[..., Tuple[Callable, Tuple]]
    default: Any
    lookup_key: Optional[Callable[..., str]] = None
    record_keys: Optional[Callable[..., Dict[str, Tuple[Tuple, float]]]] = None
    #: ``neighbors(**facts)`` yields fact-overrides for nearby tune
    #: buckets, nearest first; :func:`best` adopts the first neighbor
    #: with a recorded winner that still fits the VMEM gate for the
    #: ACTUAL facts (cross-shape warm starts without new sweeps)
    neighbors: Optional[Callable[..., Sequence[Dict[str, Any]]]] = None

    def resolve_default(self, **facts) -> Tuple:
        d = self.default
        return tuple(d(**facts)) if callable(d) else tuple(d)


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered implementation: everything dispatch, the autotuner
    and the docs need to know about it, declared in one place."""

    family: str
    name: str
    fn: Callable                               # runner, model layout
    supports: Optional[Callable[..., bool]] = None   # static capability
    layout: str = ""                           # calling-convention contract
    oracle: str = ""                           # dotted path of the oracle
    tune: Optional[TuneSpace] = None           # only on the tunable impl
    doc: str = ""


@dataclasses.dataclass
class _Family:
    name: str
    impls: "Dict[str, KernelSpec]" = dataclasses.field(default_factory=dict)
    heuristic: Optional[Callable[..., str]] = None
    facts: Optional[Callable[..., Dict[str, Any]]] = None
    layout: str = ""


_FAMILIES: Dict[str, _Family] = {}


def register_impl(family: str, name: str, *,
                  supports: Optional[Callable[..., bool]] = None,
                  layout: str = "", oracle: str = "",
                  tune: Optional[TuneSpace] = None) -> Callable:
    """Decorator: register the wrapped callable as impl ``name`` of
    ``family``.  The callable is the runner (model layout in, model
    layout out); registration is declarative — no ladder code."""
    def deco(fn: Callable) -> Callable:
        fam = _FAMILIES.setdefault(family, _Family(name=family))
        fam.impls[name] = KernelSpec(
            family=family, name=name, fn=fn, supports=supports,
            layout=layout, oracle=oracle, tune=tune,
            doc=(fn.__doc__ or "").strip().splitlines()[0]
            if fn.__doc__ else "")
        return fn
    return deco


def register_family(name: str, *, heuristic: Callable[..., str],
                    facts: Optional[Callable] = None,
                    layout: str = "") -> None:
    """Attach the unforced-selection heuristic (and, optionally, the
    static-fact extractor :func:`run` uses to self-select) to a family."""
    fam = _FAMILIES.setdefault(name, _Family(name=name))
    fam.heuristic = heuristic
    fam.facts = facts
    fam.layout = layout or fam.layout


def _family(name: str) -> _Family:
    fam = _FAMILIES.get(name)
    if fam is None:
        raise ValueError(f"unknown kernel family {name!r}; "
                         f"choose from {sorted(_FAMILIES)}")
    return fam


def families() -> Tuple[str, ...]:
    return tuple(sorted(_FAMILIES))


def impls(family: str) -> Tuple[str, ...]:
    return tuple(_family(family).impls)


def get_spec(family: str, name: str) -> KernelSpec:
    fam = _family(family)
    spec = fam.impls.get(name)
    if spec is None:
        raise ValueError(f"unknown {family} impl {name!r}; "
                         f"choose from {tuple(fam.impls)}")
    return spec


def describe() -> str:
    """Human-readable registry table (families, impls, tune spaces)."""
    lines = []
    for fname in families():
        fam = _FAMILIES[fname]
        for spec in fam.impls.values():
            tuned = "tunable" if spec.tune is not None else ""
            lines.append(f"{fname:>13}  {spec.name:<13} {tuned:<8} "
                         f"{spec.doc}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# the override ladder (one per family)
# ---------------------------------------------------------------------------

_TLS = threading.local()

#: legacy ``REPRO_ATTN_IMPL`` / ``use_attention_impl`` names, mapped onto
#: per-family overrides.  ``paged_decode`` pins the DECODE side only and
#: is transparent to prefill selection (no ``attention`` entry).
LEGACY_ATTN_MAP: Dict[str, Dict[str, str]] = {
    "pallas_flash": {"attention": "pallas_flash",
                     "paged_decode": "pallas_paged"},
    "jnp_flash": {"attention": "jnp_flash", "paged_decode": "jnp_paged"},
    "full": {"attention": "full", "paged_decode": "jnp_paged"},
    "paged_decode": {"paged_decode": "pallas_paged"},
}


def parse_impl_spec(spec: str) -> Dict[str, str]:
    """Parse ``"attention=pallas_flash,paged_decode=pallas_paged"`` into a
    validated {family: impl} mapping (the ``REPRO_IMPL`` / ``--impl``
    grammar)."""
    out: Dict[str, str] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(
                f"bad impl spec {part!r} (want family=impl[,family=impl...];"
                f" families: {families()})")
        fam, name = (t.strip() for t in part.split("=", 1))
        get_spec(fam, name)                      # validates both halves
        out[fam] = name
    return out


@contextlib.contextmanager
def use_impl(spec: Optional[str] = None, **impl_kw: Optional[str]):
    """Force per-family implementations for everything traced inside.

    Accepts a spec string (``use_impl("attention=pallas_flash")``) and/or
    keywords (``use_impl(attention="pallas_flash")``).  Thread-local
    (sweep workers never leak overrides into each other); nested
    contexts merge with inner-wins-per-family; ``None`` values are
    no-ops so callers can thread optional config fields straight
    through."""
    wanted = dict(parse_impl_spec(spec)) if spec else {}
    for fam, name in impl_kw.items():
        if name is None:
            continue
        get_spec(fam, name)                      # validate eagerly
        wanted[fam] = name
    prev = getattr(_TLS, "impls", None)
    _TLS.impls = {**(prev or {}), **wanted}
    try:
        yield
    finally:
        _TLS.impls = prev


#: the sharding facts every mesh-aware tune key understands.  Unsharded
#: call sites simply never set them (``None``), so single-device keys are
#: byte-identical to the pre-mesh scheme and stay warm.
MESH_FACTS = ("mesh_shape", "mesh_axis", "per_device_heads")


@contextlib.contextmanager
def use_mesh_facts(**facts):
    """Ambient sharding facts for everything traced inside the block.

    A mesh-aware engine enters this around its jitted programs so that
    dispatch-time :func:`best` lookups (which see only the GLOBAL array
    shapes under GSPMD) key their tune records per sharding:
    ``use_mesh_facts(mesh_shape=(1, 2), mesh_axis="model",
    per_device_heads=2)``.  Thread-local, nested contexts merge with
    inner-wins; ``None`` values are dropped so callers can thread
    optional config straight through.
    """
    wanted = {k: v for k, v in facts.items() if v is not None}
    unknown = set(wanted) - set(MESH_FACTS)
    if unknown:
        raise ValueError(f"unknown mesh facts {sorted(unknown)}; "
                         f"expected a subset of {MESH_FACTS}")
    prev = getattr(_TLS, "mesh_facts", None)
    _TLS.mesh_facts = {**(prev or {}), **wanted}
    try:
        yield
    finally:
        _TLS.mesh_facts = prev


def mesh_facts() -> Dict[str, Any]:
    """The ambient sharding facts (empty dict when unsharded)."""
    return dict(getattr(_TLS, "mesh_facts", None) or {})


def mesh_key_tag(*, mesh_shape=None, mesh_axis=None,
                 per_device_heads=None) -> str:
    """Tune-key component for a sharding: '' unsharded (keys unchanged),
    ``-mesh1x2.model.pdh2`` under a (1, 2) mesh with the kv heads split
    over ``model`` leaving 2 per device."""
    if mesh_shape is None:
        return ""
    shape = "x".join(str(int(s)) for s in mesh_shape)
    pdh = ("" if per_device_heads is None
           else f".pdh{int(per_device_heads)}")
    return f"-mesh{shape}.{mesh_axis or 'model'}{pdh}"


def _unsharded_fallback(facts: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Neighbor delta clearing the mesh facts: under a sharding the
    UNSHARDED key is the fallback neighbor (a single-device sweep is a
    better prior than the declared default), tried after the same-
    sharding shape neighbors."""
    if facts.get("mesh_shape") is None:
        return []
    return [{k: None for k in MESH_FACTS}]


def override_for(family: str) -> Optional[str]:
    """The forced impl for ``family``: context, else ``REPRO_IMPL``, else
    the legacy ``REPRO_ATTN_IMPL`` mapping; None when unforced."""
    ctx = getattr(_TLS, "impls", None)
    if ctx and family in ctx:
        return ctx[family]
    env = os.environ.get("REPRO_IMPL")
    if env:
        mapping = parse_impl_spec(env)           # raises on bad spec
        if family in mapping:
            return mapping[family]
    legacy = os.environ.get("REPRO_ATTN_IMPL")
    if legacy:
        mapping = LEGACY_ATTN_MAP.get(legacy)
        if mapping is None:
            raise ValueError(f"REPRO_ATTN_IMPL={legacy!r} not in "
                             f"{tuple(LEGACY_ATTN_MAP)}")
        if family in mapping:
            return mapping[family]
    return None


def select(family: str, **facts) -> str:
    """Pick an implementation name from STATIC facts only (trace-time).

    An override (context / env) beats every heuristic — including
    capability hints like ``differentiable`` — exactly as the legacy
    attention ladder behaved.  Unforced, the family's registered
    heuristic decides."""
    fam = _family(family)
    forced = override_for(family)
    if forced is not None:
        get_spec(family, forced)                 # late env validation
        return forced
    if fam.heuristic is None:
        # declarative fallback: first impl whose capability predicate
        # accepts these facts
        for spec in fam.impls.values():
            if spec.supports is None or spec.supports(**facts):
                return spec.name
        raise ValueError(f"no {family} impl supports {facts}")
    return fam.heuristic(**facts)


def run(family: str, *args, impl: Optional[str] = None, **kwargs):
    """Run ``family`` on model-layout args; ``impl=None`` self-selects
    via the family's fact extractor + :func:`select`."""
    fam = _family(family)
    if impl is None:
        facts = fam.facts(*args, **kwargs) if fam.facts is not None else {}
        impl = select(family, **facts)
    return get_spec(family, impl).fn(*args, **kwargs)


# ---------------------------------------------------------------------------
# the tune table (lock-guarded: sweep workers race on it) + persistence
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TuneRecord:
    """Outcome of one autotune sweep — or its disk-persisted resurrection
    (``swept=False``: served from the tune cache, zero measurements)."""

    family: str
    key: str
    choice: Tuple
    score_s: float
    scores: Dict[Tuple, float]          # candidate -> score (inf = gated)
    lowerings: int                      # real compiles (0 = fully warm)
    swept: bool = True                  # False: loaded, not measured
    #: winner's measured artifact events (FLOPS_TOTAL / BYTES_ACCESSED) —
    #: what perf_report needs to place the choice on the roofline
    winner_events: Dict[str, float] = dataclasses.field(default_factory=dict)
    interpolated: bool = False          # adopted from a neighbor bucket


class _TuneTable:
    """The process-wide winner table, consulted by :func:`best` on every
    dispatch.  Every access is lock-guarded: ``ProfileSession.sweep``
    workers autotune concurrently (the PR-3/PR-4 dicts raced here).

    Disk misses are negative-cached (``note_miss``/``missed``) so an
    untuned shape pays the filesystem probe once per process, not once
    per dispatch; recording a key discards its miss marker."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._recs: Dict[Tuple[str, str], TuneRecord] = {}
        self._miss: set = set()

    def get(self, family: str, key: str) -> Optional[TuneRecord]:
        with self._lock:
            return self._recs.get((family, key))

    def put(self, rec: TuneRecord) -> None:
        with self._lock:
            self._recs[(rec.family, rec.key)] = rec
            self._miss.discard((rec.family, rec.key))

    def missed(self, family: str, key: str) -> bool:
        with self._lock:
            return (family, key) in self._miss

    def note_miss(self, family: str, key: str) -> None:
        with self._lock:
            self._miss.add((family, key))

    def drop_misses(self) -> None:
        """Invalidate every negative-cached miss (records stay): the set
        of disk roots just changed, so a prior miss proves nothing."""
        with self._lock:
            self._miss.clear()

    def clear(self, family: Optional[str] = None) -> None:
        with self._lock:
            if family is None:
                self._recs.clear()
                self._miss.clear()
            else:
                for k in [k for k in self._recs if k[0] == family]:
                    del self._recs[k]
                self._miss = {k for k in self._miss if k[0] != family}

    def snapshot(self) -> List[TuneRecord]:
        with self._lock:
            return list(self._recs.values())

    def __len__(self) -> int:
        with self._lock:
            return len(self._recs)


_TABLE = _TuneTable()


def tune_table() -> _TuneTable:
    return _TABLE


def clear_tune_table(family: Optional[str] = None) -> None:
    """Forget everything this process learned about winners: the table,
    the negative-cached misses and (on a full clear) the extra cache
    roots.  Disk-persisted records survive — ``best`` re-reads the
    default root on the next miss."""
    _TABLE.clear(family)
    if family is None:
        _forget_tune_roots()


def dump_tune_table() -> Dict[str, Any]:
    """JSON-ready dump of every in-process record (the CI artifact)."""
    return {"records": [
        {"family": r.family, "key": r.key, "choice": list(r.choice),
         "score_s": r.score_s, "lowerings": r.lowerings, "swept": r.swept,
         "scores": {str(list(c)): s for c, s in sorted(r.scores.items())},
         "winner_events": dict(r.winner_events),
         "interpolated": r.interpolated}
        for r in sorted(_TABLE.snapshot(), key=lambda r: (r.family, r.key))
    ]}


def _toolchain() -> Dict[str, str]:
    from repro.core.session import _toolchain as tc
    return tc()


def _tune_digest(kind: str, family: str, key: str) -> str:
    """Content digest for a persisted tune entry — keyed like probes
    (toolchain includes the whole-repo source fingerprint, so a code
    edit invalidates winners instead of serving stale tilings)."""
    return canonical_digest({"kind": kind, "family": family, "key": key,
                             "toolchain": _toolchain()})


# cache roots autotune persisted winners to this process, beyond the
# default root — best() consults these too, so a custom
# ProfileSession(cache_dir=...) sweep is visible to dispatch even after
# clear_tune_table().  (Cross-process, best()-only warm starts read the
# DEFAULT root: point $REPRO_CACHE_DIR at the sweep's cache dir, or call
# autotune once per process — free when warm — to re-register the root.)
# Lock-guarded: sweep workers add roots while dispatches snapshot them.
_EXTRA_TUNE_ROOTS: set = set()
_ROOTS_LOCK = threading.Lock()


def _note_tune_root(cache: ArtifactCache) -> None:
    if cache.enabled and cache.root != ArtifactCache(None).root:
        with _ROOTS_LOCK:
            fresh = cache.root not in _EXTRA_TUNE_ROOTS
            _EXTRA_TUNE_ROOTS.add(cache.root)
        if fresh:
            # misses negative-cached BEFORE this root became visible are
            # stale: keys absent from the old roots may be persisted
            # here (e.g. after clear_tune_table() forgot the root)
            _TABLE.drop_misses()


def _forget_tune_roots() -> None:
    with _ROOTS_LOCK:
        _EXTRA_TUNE_ROOTS.clear()


def _tune_caches() -> List[ArtifactCache]:
    """The caches :func:`best` reads when the in-process table misses —
    ``$REPRO_CACHE_DIR`` (resolved per call, i.e. the place
    ProfileSession probes land by default) plus any roots winners were
    persisted to this process."""
    default = ArtifactCache(None)
    with _ROOTS_LOCK:
        extras = sorted(_EXTRA_TUNE_ROOTS)
    return [default] + [ArtifactCache(r) for r in extras
                        if r != default.root]


def _rec_to_entry(rec: TuneRecord, candidates: Sequence[Tuple],
                  vmem_fraction: float,
                  records: Dict[str, Tuple[Tuple, float]],
                  rec_events: Dict[str, Dict[str, float]]) -> Dict[str, Any]:
    return {
        "kind": "tune-sweep", "family": rec.family, "key": rec.key,
        "choice": list(rec.choice), "score_s": rec.score_s,
        "scores": [[list(c), s] for c, s in rec.scores.items()],
        "candidates": [list(c) for c in candidates],
        "vmem_fraction": vmem_fraction,
        "winner_events": dict(rec.winner_events),
        "records": {k: {"choice": list(c), "score_s": s,
                        "winner_events": rec_events.get(k, {})}
                    for k, (c, s) in records.items()},
    }


#: digests already warned about this process — corrupt tune entries warn
#: ONCE, not per lookup (dispatch consults the table on every call)
_QUARANTINE_WARNED: set = set()
_QUARANTINE_LOCK = threading.Lock()


def _quarantine_tune_entry(cache: ArtifactCache, digest: str, family: str,
                           key: str, err: Exception) -> None:
    """A persisted tune-table entry failed to parse: move it aside as
    ``*.corrupt`` (post-mortem evidence, never served again), warn once
    per process, and let the caller fall through to a re-sweep/miss —
    a damaged cache degrades to a cold cache, never to a crash."""
    cache.quarantine(digest)
    with _QUARANTINE_LOCK:
        if digest in _QUARANTINE_WARNED:
            return
        _QUARANTINE_WARNED.add(digest)
    warnings.warn(
        f"corrupt tune-table entry for {family}[{key}] "
        f"({type(err).__name__}: {err}) quarantined to *.corrupt under "
        f"{cache.root}; re-sweeping", RuntimeWarning, stacklevel=3)


def _entry_to_rec(family: str, key: str, entry: Dict[str, Any]) -> TuneRecord:
    return TuneRecord(
        family=family, key=key, choice=tuple(entry["choice"]),
        score_s=float(entry["score_s"]),
        scores={tuple(c): float(s) for c, s in entry["scores"]},
        lowerings=0, swept=False,
        winner_events=dict(entry.get("winner_events") or {}))


def _roofline_seconds(ev, chip: hwinfo.ChipSpec) -> float:
    """max(compute term, memory term) from measured artifact events."""
    t_c = ev["FLOPS_TOTAL"] / chip.peak_bf16_flops
    t_m = ev["BYTES_ACCESSED"] / chip.hbm_bw
    return max(t_c, t_m)


def _tuned_spec(family: str, impl: Optional[str] = None) -> KernelSpec:
    fam = _family(family)
    if impl is not None:
        spec = get_spec(family, impl)
        if spec.tune is None:
            raise ValueError(f"{family}/{impl} declares no tune space")
        return spec
    tuned = [s for s in fam.impls.values() if s.tune is not None]
    if not tuned:
        raise ValueError(f"family {family!r} has no tunable impl")
    return tuned[0]


def autotune(family: str, session, *, impl: Optional[str] = None,
             candidates: Optional[Sequence[Tuple]] = None,
             chip: Optional[hwinfo.ChipSpec] = None,
             backend: Optional[str] = None,
             interpret: Optional[bool] = None,
             vmem_fraction: float = 0.9, force: bool = False,
             **facts) -> TuneRecord:
    """Sweep the family's tune space for one shape; record + persist the
    winner(s).

    Warm start is two-level: a sweep whose persisted record matches
    (same tune key, same candidate set, same VMEM budget, same
    toolchain) returns WITHOUT measuring anything (``swept=False`` —
    zero sweeps, zero lowerings); a changed candidate set re-sweeps, but
    each probe is itself a content-addressed cache entry, so even that
    re-lowers nothing that was measured before.  ``force=True`` ignores
    the persisted record.  Winners land in the lock-guarded table
    :func:`best` consults and on disk for the next process.
    """
    spec = _tuned_spec(family, impl)
    ts = spec.tune
    chip = chip or getattr(session, "chip", None) or hwinfo.DEFAULT_CHIP
    backend = _backend(backend)
    if interpret is None:
        interpret = default_interpret(backend)
    facts = {**mesh_facts(), **facts}
    facts = dict(facts, backend=backend)
    facts.setdefault("dtype", jnp.float32)
    key = ts.key(**facts)
    cands = tuple(tuple(c) for c in
                  (candidates if candidates is not None
                   else ts.candidates(**facts)))

    _note_tune_root(session.cache)
    digest = _tune_digest("tune-sweep", family, key)
    if not force:
        entry = session.cache.get(digest)
        if (entry is not None
                and entry.get("candidates") == [list(c) for c in cands]
                and entry.get("vmem_fraction") == vmem_fraction):
            try:
                rec = _entry_to_rec(family, key, entry)
                subs = [(rkey, tuple(sub["choice"]), float(sub["score_s"]),
                         dict(sub.get("winner_events") or {}))
                        for rkey, sub in (entry.get("records") or {}).items()]
            except (KeyError, TypeError, ValueError, AttributeError) as e:
                # schema-valid JSON, garbage content (truncated write,
                # hand edit, version skew): quarantine + fall through to
                # a fresh sweep instead of crashing dispatch
                _quarantine_tune_entry(session.cache, digest, family,
                                       key, e)
            else:
                for rkey, rchoice, rscore, rev in subs:
                    _TABLE.put(TuneRecord(
                        family=family, key=rkey, choice=rchoice,
                        score_s=rscore, scores=rec.scores,
                        lowerings=0, swept=False, winner_events=rev))
                return rec

    itemsize = jnp.dtype(facts["dtype"]).itemsize
    budget = chip.vmem_bytes * vmem_fraction
    lowerings0 = session.lowerings
    scores: Dict[Tuple, float] = {}
    cand_events: Dict[Tuple, Dict[str, float]] = {}
    for cand in cands:
        if ts.vmem(cand, itemsize, **facts) > budget:
            scores[cand] = float("inf")          # gated before any XLA work
            continue
        fn, abstract_args = ts.probe(cand, interpret, **facts)
        m = session.measure(fn, *abstract_args,
                            region=f"{family}[{key}]{list(cand)}", chip=chip)
        scores[cand] = _roofline_seconds(m.events, chip)
        cand_events[cand] = {
            "FLOPS_TOTAL": float(m.events["FLOPS_TOTAL"]),
            "BYTES_ACCESSED": float(m.events["BYTES_ACCESSED"]),
        }

    finite = {c: s for c, s in scores.items() if s != float("inf")}
    if not finite:
        raise ValueError(f"no {family} candidate fits VMEM for {key} "
                         f"(candidates {cands})")
    choice, score = min(finite.items(), key=lambda kv: (kv[1], kv[0]))
    lowerings = session.lowerings - lowerings0
    rec = TuneRecord(family=family, key=key, choice=choice, score_s=score,
                     scores=scores, lowerings=lowerings, swept=True,
                     winner_events=cand_events.get(choice, {}))

    if ts.record_keys is not None:
        records = ts.record_keys(scores, **facts)
    else:
        records = {key: (choice, score)}
    rec_events = {rkey: cand_events.get(tuple(rchoice), {})
                  for rkey, (rchoice, _s) in records.items()}
    for rkey, (rchoice, rscore) in records.items():
        _TABLE.put(TuneRecord(family=family, key=rkey,
                              choice=tuple(rchoice), score_s=rscore,
                              scores=scores, lowerings=lowerings,
                              swept=True,
                              winner_events=rec_events.get(rkey, {})))
    session.cache.put(digest, _rec_to_entry(rec, cands, vmem_fraction,
                                            records, rec_events))
    for rkey, (rchoice, rscore) in records.items():
        session.cache.put(
            _tune_digest("tune-choice", family, rkey),
            {"kind": "tune-choice", "family": family, "key": rkey,
             "choice": list(rchoice), "score_s": rscore,
             "winner_events": rec_events.get(rkey, {})})
    return rec


def _best_from_disk(family: str, key: str) -> Optional[Tuple]:
    """Resolve one tune key from the persisted caches; loads the record
    into the table on a hit, returns None (without negative-caching —
    the caller decides) on a miss."""
    digest = _tune_digest("tune-choice", family, key)
    for cache in _tune_caches():
        entry = cache.get(digest)
        if entry is None or "choice" not in entry:
            continue
        try:
            choice = tuple(entry["choice"])
            rec = TuneRecord(
                family=family, key=key, choice=choice,
                score_s=float(entry.get("score_s", "nan")),
                scores={}, lowerings=0, swept=False,
                winner_events=dict(entry.get("winner_events") or {}))
        except (TypeError, ValueError, AttributeError) as e:
            # a damaged persisted winner reads as a miss in THIS cache;
            # later roots may still hold a healthy copy
            _quarantine_tune_entry(cache, digest, family, key, e)
            continue
        _TABLE.put(rec)
        return choice
    return None


def _best_from_neighbors(family: str, ts: TuneSpace,
                         keyf: Callable[..., str], exact_key: str,
                         facts: Dict[str, Any]) -> Optional[Tuple]:
    """Cross-shape generalization: adopt the nearest tuned bucket's
    winner instead of falling to the declared default.  A neighbor's
    choice is only adopted when it passes the spec's VMEM gate for the
    ACTUAL facts (the same 0.9 budget the tuner uses); the adoption is
    recorded under the exact key (``interpolated=True``), so dispatch
    pays the neighbor scan once per process per shape."""
    itemsize = jnp.dtype(facts["dtype"]).itemsize
    budget = hwinfo.DEFAULT_CHIP.vmem_bytes * 0.9
    for delta in ts.neighbors(**facts):
        nfacts = {**facts, **delta}
        nkey = keyf(**nfacts)
        if nkey == exact_key:
            continue
        rec = _TABLE.get(family, nkey)
        if rec is None and not _TABLE.missed(family, nkey):
            if _best_from_disk(family, nkey) is None:
                _TABLE.note_miss(family, nkey)
            else:
                rec = _TABLE.get(family, nkey)
        if rec is None:
            continue
        choice = rec.choice
        if ts.vmem(tuple(choice), itemsize, **facts) > budget:
            continue                     # oversized for the actual shape
        _TABLE.put(TuneRecord(
            family=family, key=exact_key, choice=tuple(choice),
            score_s=rec.score_s, scores={}, lowerings=0, swept=False,
            winner_events=dict(rec.winner_events), interpolated=True))
        return tuple(choice)
    return None


def best(family: str, *, impl: Optional[str] = None, **facts) -> Tuple:
    """The tuned choice for this shape: in-process table, else the
    disk-persisted record (a fresh process warm-starts with zero
    sweeps), else — for families declaring a ``neighbors`` hook — the
    nearest tuned bucket's winner (VMEM-gated for the actual shape),
    else the spec's declared default.  Called by runners at trace time
    on every dispatch; a disk miss is negative-cached so untuned shapes
    probe the filesystem once per process.

    Ambient :func:`use_mesh_facts` merge in under explicit facts, so a
    mesh-aware engine's dispatch sites resolve per-sharding records
    without every kernel threading mesh state by hand; the unsharded key
    doubles as the fallback neighbor (:func:`_unsharded_fallback`)."""
    ts = _tuned_spec(family, impl).tune
    facts = {**mesh_facts(), **facts}
    facts = dict(facts, backend=_backend(facts.get("backend")))
    facts.setdefault("dtype", jnp.float32)
    keyf = ts.lookup_key or ts.key
    key = keyf(**facts)
    rec = _TABLE.get(family, key)
    if rec is not None:
        return rec.choice
    if not _TABLE.missed(family, key):
        choice = _best_from_disk(family, key)
        if choice is not None:
            return choice
        _TABLE.note_miss(family, key)
    if ts.neighbors is not None:
        choice = _best_from_neighbors(family, ts, keyf, key, facts)
        if choice is not None:
            return choice
    return ts.resolve_default(**facts)


def record(family: str, key: str, choice: Tuple,
           score_s: float = float("nan")) -> None:
    """Pin a choice manually (e.g. replayed from a saved bench record);
    in-process only."""
    _TABLE.put(TuneRecord(family=family, key=key, choice=tuple(choice),
                          score_s=score_s, scores={}, lowerings=0,
                          swept=False))


# ===========================================================================
# family: attention (prefill / dense attention, BSHD)
# ===========================================================================

DEFAULT_BLOCKS: Tuple[int, int] = (128, 256)

#: (bq, bk) grid — multiples of the 8-sublane/128-lane layout quanta
DEFAULT_CANDIDATES: Tuple[Tuple[int, int], ...] = (
    (64, 64), (64, 128), (128, 128), (128, 256), (256, 128), (256, 256),
    (512, 256),
)


def attention_tune_key(*, b: int, h: int, kvh: int, sq: int, sk: int,
                       dh: int, dtype, causal: bool = True,
                       backend: Optional[str] = None,
                       mesh_shape=None, mesh_axis=None,
                       per_device_heads=None, **_ignored) -> str:
    """Per-shape tune key.  ``b`` is bucketed to powers of two (the
    lesson ``paged_tune_key`` learned for table width): the continuous-
    batching scheduler's live mix varies batch from segment to segment,
    and a winning (bq, bk) tiling is a per-row property — keying on the
    exact batch made every serving lookup miss the sweep's record and
    fall back to DEFAULT_BLOCKS.  Under a mesh the sharding facts join
    the key (:func:`mesh_key_tag`): each device runs the kernel over its
    head slice, so the winning tiling is a per-sharding property."""
    return (f"b{_pow2_up(b)}h{h}kvh{kvh}sq{sq}sk{sk}dh{dh}"
            f"-{_dtype_name(dtype)}-{'causal' if causal else 'full'}"
            f"-{_backend(backend)}"
            + mesh_key_tag(mesh_shape=mesh_shape, mesh_axis=mesh_axis,
                           per_device_heads=per_device_heads))


def attention_vmem(bq: int, bk: int, dh: int, itemsize: int = 4) -> int:
    """Bytes of VMEM the flash kernel needs for one (bq, bk) tile pair:
    I/O tiles (q, k, v, out) double-buffered by the pipeline, the
    [bq,bk] f32 score tile plus m/l/acc scratch rows live once."""
    io = 2 * (bq * dh + 2 * bk * dh + bq * dh) * itemsize
    compute = (bq * bk + bq * dh + 2 * bq) * 4
    return io + compute


def _attention_vmem(cand, itemsize, *, sq, sk, dh, **facts) -> int:
    bq, bk = cand
    return attention_vmem(min(bq, sq), min(bk, sk), dh, itemsize)


def _flash_probe(q, k, v, kv_valid, *, causal: bool, bq: int, bk: int,
                 interpret: bool):
    """Module-level probe target: partial-wrapping this per candidate
    gives every (bq, bk) a stable cross-process fingerprint."""
    from repro.kernels.flash_attention import flash_attention_bhsd
    return flash_attention_bhsd(q, k, v, causal=causal, kv_valid=kv_valid,
                                bq=bq, bk=bk, interpret=interpret)


def _attention_probe(cand, interpret, *, b, h, kvh, sq, sk, dh, dtype,
                     causal=True, **facts):
    bq, bk = cand
    fn = functools.partial(_flash_probe, causal=causal, bq=bq, bk=bk,
                           interpret=interpret)
    args = (jax.ShapeDtypeStruct((b, h, sq, dh), dtype),
            jax.ShapeDtypeStruct((b, kvh, sk, dh), dtype),
            jax.ShapeDtypeStruct((b, kvh, sk, dh), dtype),
            jax.ShapeDtypeStruct((b,), jnp.int32))
    return fn, args


def _attention_neighbors(*, b: int, sq: int, sk: int, **_facts
                         ) -> List[Dict[str, Any]]:
    """Nearby tuned buckets, nearest first: the batch bucket one/two
    pow2 steps away (same sequence — a winning (bq, bk) tiling is a
    per-row property), then the whole sequence scaled by pow2 (sq and
    sk together, so a smoke-swept 128/192 cell warm-starts the 256/384
    serving shape and vice versa)."""
    out: List[Dict[str, Any]] = []
    bb = _pow2_up(b)
    for f in (2, 4):
        if bb // f >= 1:
            out.append({"b": bb // f})
        out.append({"b": bb * f})
    for f in (2, 4):
        if sq // f >= 1 and sk // f >= 1:
            out.append({"sq": sq // f, "sk": sk // f})
        out.append({"sq": sq * f, "sk": sk * f})
    out.extend(_unsharded_fallback(_facts))
    return out


_ATTENTION_TUNE = TuneSpace(
    key=attention_tune_key,
    candidates=lambda **f: DEFAULT_CANDIDATES,
    vmem=_attention_vmem,
    probe=_attention_probe,
    default=DEFAULT_BLOCKS,
    neighbors=_attention_neighbors,
)

_ATTENTION_LAYOUT = ("q [B,Sq,H,Dh]; k/v [B,Sk,KVH,Dh] -> [B,Sq,H,Dh]; "
                     "q_offset scalar, kv_len scalar or [B] (traced ok)")


def _attention_facts(q, k, v, *, causal: bool = True,
                     chunk_threshold: int = 2048, **_kw) -> Dict[str, Any]:
    return dict(sq=q.shape[1], sk=k.shape[1], dh=q.shape[-1], causal=causal,
                flash_min_seq=chunk_threshold)


def _attention_heuristic(*, sq: int, sk: int, dh: int, causal: bool = True,
                         backend: Optional[str] = None,
                         flash_min_seq: Optional[int] = None,
                         differentiable: bool = False) -> str:
    del sk, causal                  # part of the contract, unused for now
    if differentiable:
        return "jnp_flash"          # the Pallas kernel is forward-only
    backend = _backend(backend)
    if backend == "tpu":
        # MXU-shaped work only; degenerate shapes stay on fused XLA ops
        return "pallas_flash" if (sq >= 8 and dh % 8 == 0) else "full"
    if flash_min_seq is not None and sq > flash_min_seq:
        return "jnp_flash"
    return "full"


register_family("attention", heuristic=_attention_heuristic,
                facts=_attention_facts, layout=_ATTENTION_LAYOUT)


@register_impl("attention", "pallas_flash", tune=_ATTENTION_TUNE,
               layout=_ATTENTION_LAYOUT,
               oracle="repro.kernels.ref.flash_attention",
               # mesh fact: the kernel needs at least one whole kv head
               # per device (per_device_heads=0 marks an indivisible
               # head sharding — the fused-XLA paths handle that)
               supports=lambda *, differentiable=False,
                   per_device_heads=None, **f:
                   not differentiable and (per_device_heads is None
                                           or per_device_heads >= 1))
def _run_pallas_flash(q, k, v, *, q_offset=0, causal: bool = True,
                      kv_len=None, softmax_mode: str = "naive",
                      chunk_size: int = 512, chunk_threshold: int = 2048,
                      blocks: Optional[Tuple[int, int]] = None,
                      interpret: Optional[bool] = None):
    """flash_attention_bhsd — blockwise online-softmax GQA (forward-only)."""
    from repro.kernels import ops
    b, sq, h, dh = q.shape
    bq, bk = blocks or best("attention", b=b, h=h, kvh=k.shape[2], sq=sq,
                            sk=k.shape[1], dh=dh, dtype=q.dtype,
                            causal=causal)
    # ops.flash_attention owns the BSHD<->BHSD layout contract
    return ops.flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                               kv_valid=kv_len, bq=bq, bk=bk,
                               interpret=interpret)


@register_impl("attention", "jnp_flash", layout=_ATTENTION_LAYOUT,
               oracle="repro.kernels.ref.flash_attention")
def _run_jnp_flash(q, k, v, *, q_offset=0, causal: bool = True, kv_len=None,
                   softmax_mode: str = "naive", chunk_size: int = 512,
                   chunk_threshold: int = 2048, blocks=None, interpret=None):
    """online-softmax twin with the flash custom-VJP (training-safe)."""
    from repro.models.attention import _flash_attention_offset
    return _flash_attention_offset(q, k, v, q_offset, causal, kv_len=kv_len)


@register_impl("attention", "full", layout=_ATTENTION_LAYOUT,
               oracle="repro.kernels.ref.flash_attention")
def _run_full(q, k, v, *, q_offset=0, causal: bool = True, kv_len=None,
              softmax_mode: str = "naive", chunk_size: int = 512,
              chunk_threshold: int = 2048, blocks=None, interpret=None):
    """scores-materialized naive/fused attention (paper-faithful baseline)."""
    from repro.models import attention as attn_mod
    mode = "naive" if softmax_mode == "kernel" else softmax_mode
    # the q-chunked scan derives its own offsets from 0, so it only
    # substitutes for the flat path when q really starts at 0
    if (q.shape[1] > chunk_threshold
            and isinstance(q_offset, int) and q_offset == 0):
        return attn_mod._chunked_attention(q, k, v, chunk_size, causal,
                                           mode, kv_len=kv_len)
    return attn_mod._full_attention_offset(q, k, v, q_offset, causal,
                                           mode, kv_len=kv_len)


# ===========================================================================
# family: paged_decode (decode attention over the serve/kv_pool pages)
# ===========================================================================

DEFAULT_PAGES_PER_BLOCK = 1

#: (page_size, pages_per_block) grid — page_size trades pool
#: fragmentation against per-page DMA efficiency, pages_per_block is the
#: kernel's fetch granularity over a row's table
DEFAULT_PAGED_CANDIDATES: Tuple[Tuple[int, int], ...] = (
    (16, 1), (16, 2), (16, 4), (32, 1), (32, 2), (32, 4),
    (64, 1), (64, 2), (128, 1),
)


def _paged_ctx_bucket(ctx) -> int:
    """Context is bucketed to powers of two: the scheduler's live table
    width drifts segment to segment, and a fetch granularity tuned at
    ctx=512 serves ctx=700 fine — pow2 buckets + the neighbors hook keep
    lookups warm across the whole mixed-context sweep."""
    return _pow2_up(max(int(ctx), 1))


def paged_lookup_key(*, b: int, kvh: int, g: int, dh: int, page_size: int,
                     dtype, ctx: int = 0, backend: Optional[str] = None,
                     quantized: bool = False,
                     mesh_shape=None, mesh_axis=None,
                     per_device_heads=None, **_ignored) -> str:
    # keyed on the pow2 ctx BUCKET, not the raw page-table width: the
    # scheduler's live-mix bucket changes segment to segment, and the
    # winning fetch granularity is a per-page property — exact-width keys
    # would make every serving lookup miss the sweep's record.  Mesh
    # facts join the key: each device walks its kv-head slice of the
    # page pool, so the fetch granularity is a per-sharding property.
    tag = "q8" if quantized else ""
    return (f"paged{tag}-b{b}kvh{kvh}g{g}dh{dh}ps{page_size}"
            f"ctx{_paged_ctx_bucket(ctx)}"
            f"-{_dtype_name(dtype)}-{_backend(backend)}"
            + mesh_key_tag(mesh_shape=mesh_shape, mesh_axis=mesh_axis,
                           per_device_heads=per_device_heads))


def paged_sweep_key(*, b: int, kvh: int, g: int, dh: int, ctx: int, dtype,
                    backend: Optional[str] = None,
                    quantized: bool = False,
                    mesh_shape=None, mesh_axis=None,
                    per_device_heads=None, **_ignored) -> str:
    tag = "q8" if quantized else ""
    return (f"paged{tag}-sweep-b{b}kvh{kvh}g{g}dh{dh}ctx{ctx}"
            f"-{_dtype_name(dtype)}-{_backend(backend)}"
            + mesh_key_tag(mesh_shape=mesh_shape, mesh_axis=mesh_axis,
                           per_device_heads=per_device_heads))


def paged_vmem(ps: int, ppb: int, g: int, dh: int, itemsize: int = 4) -> int:
    """VMEM bytes for one grid step: q + ppb double-buffered k/v page
    tiles + out, plus the f32 [g, ps] score tile and m/l/acc scratch."""
    io = 2 * (g * dh + 2 * ppb * ps * dh + 2 * dh + g * dh) * itemsize
    compute = (g * ps + g * dh + 2 * g) * 4
    return io + compute


def _paged_vmem(cand, itemsize, *, g, dh, **facts) -> int:
    ps, ppb = cand
    return paged_vmem(ps, ppb, g, dh, itemsize)


def _paged_probe_fn(q4, kp, vp, pt, lens, kn, vn, *, ppb: int,
                    interpret: bool):
    """Module-level probe target (stable fingerprint per (page_size via
    shapes, ppb via partial) candidate)."""
    from repro.kernels.paged_decode import paged_decode_attention_grouped
    return paged_decode_attention_grouped(q4, kp, vp, pt, lens, kn, vn,
                                          pages_per_block=ppb,
                                          interpret=interpret)


def _paged_probe(cand, interpret, *, b, kvh, g, dh, ctx, dtype, **facts):
    ps, ppb = cand
    np_w = max(-(-ctx // ps), 1)
    p_total = b * np_w + 1
    fn = functools.partial(_paged_probe_fn, ppb=ppb, interpret=interpret)
    kp_s = jax.ShapeDtypeStruct((p_total, ps, kvh, dh), dtype)
    kn_s = jax.ShapeDtypeStruct((b, kvh, dh), dtype)
    args = (jax.ShapeDtypeStruct((b, kvh, g, dh), dtype), kp_s, kp_s,
            jax.ShapeDtypeStruct((b, np_w), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32), kn_s, kn_s)
    return fn, args


def _paged_record_keys(scores, *, b, kvh, g, dh, dtype, ctx=0, backend=None,
                       quantized: bool = False,
                       mesh_shape=None, mesh_axis=None,
                       per_device_heads=None,
                       **facts) -> Dict[str, Tuple[Tuple, float]]:
    """One lookup record per swept page_size: whatever page_size the pool
    was built with, dispatch finds its winning fetch granularity.  Mesh
    facts fan out with the sweep's — a per-sharding sweep warms every
    page_size under that same sharding."""
    per_ps: Dict[int, Tuple[Tuple, float]] = {}
    for (ps, ppb), s in scores.items():
        if s == float("inf"):
            continue
        cur = per_ps.get(ps)
        if cur is None or (s, ppb) < (cur[1], cur[0][1]):
            per_ps[ps] = ((ps, ppb), s)
    return {paged_lookup_key(b=b, kvh=kvh, g=g, dh=dh, page_size=ps,
                             ctx=ctx, dtype=dtype, backend=backend,
                             quantized=quantized, mesh_shape=mesh_shape,
                             mesh_axis=mesh_axis,
                             per_device_heads=per_device_heads): rec
            for ps, rec in per_ps.items()}


def _paged_neighbors(*, b: int, ctx: int = 0, **_facts
                     ) -> List[Dict[str, Any]]:
    """Nearby paged tune buckets, nearest first: the ctx bucket one/two
    pow2 steps away (the shared-prefix scheduler's live context widths
    vary request to request while the per-page fetch granularity barely
    moves), then the batch scaled the same way (slot-count drift)."""
    out: List[Dict[str, Any]] = []
    cb = _paged_ctx_bucket(ctx)
    for f in (2, 4):
        if cb // f >= 1:
            out.append({"ctx": cb // f})
        out.append({"ctx": cb * f})
    for f in (2, 4):
        if b // f >= 1:
            out.append({"b": b // f})
        out.append({"b": b * f})
    out.extend(_unsharded_fallback(_facts))
    return out


_PAGED_TUNE = TuneSpace(
    key=paged_sweep_key,
    candidates=lambda **f: DEFAULT_PAGED_CANDIDATES,
    vmem=_paged_vmem,
    probe=_paged_probe,
    default=lambda *, page_size, **f: (page_size, DEFAULT_PAGES_PER_BLOCK),
    lookup_key=paged_lookup_key,
    record_keys=_paged_record_keys,
    neighbors=_paged_neighbors,
)

_PAGED_LAYOUT = ("q [B,1,H,Dh]; k/v_pages [P,ps,KVH,Dh]; page_table "
                 "[B,NP] i32; length [B] i32; k/v_new [B,1,KVH,Dh] "
                 "-> [B,1,H,Dh]")

_PAGED_Q8_LAYOUT = (_PAGED_LAYOUT +
                    "; int8 pages + k/v_scale [P,ps] f32 per-token scales")


# --- int8 tune space: same candidate grid, its own keys (the winning
# fetch granularity differs when pages are 4x smaller on the wire), a
# probe over int8 pages + f32 scales, and a VMEM model that prices the
# int8 tiles at 1 byte plus their f32 dequantized copies

def _paged_q8_sweep_key(**facts) -> str:
    facts.pop("quantized", None)
    return paged_sweep_key(quantized=True, **facts)


def _paged_q8_lookup_key(**facts) -> str:
    facts.pop("quantized", None)
    return paged_lookup_key(quantized=True, **facts)


def _paged_q8_record_keys(scores, **facts) -> Dict[str, Tuple[Tuple, float]]:
    facts.pop("quantized", None)
    return _paged_record_keys(scores, quantized=True, **facts)


def _paged_q8_vmem(cand, itemsize, *, g, dh, **facts) -> int:
    ps, ppb = cand
    io = 2 * ((2 * g * dh + 2 * dh) * itemsize     # q, out, k/v_new
              + 2 * ppb * ps * dh                  # int8 k/v page tiles
              + 2 * ppb * ps * 4)                  # f32 scale tiles
    compute = (2 * ppb * ps * dh + g * ps + g * dh + 2 * g) * 4
    return io + compute


def _paged_q8_probe_fn(q4, kp, vp, ksc, vsc, pt, lens, kn, vn, *, ppb: int,
                       interpret: bool):
    from repro.kernels.paged_decode import paged_decode_attention_q8_grouped
    return paged_decode_attention_q8_grouped(q4, kp, vp, ksc, vsc, pt, lens,
                                             kn, vn, pages_per_block=ppb,
                                             interpret=interpret)


def _paged_q8_probe(cand, interpret, *, b, kvh, g, dh, ctx, dtype, **facts):
    ps, ppb = cand
    np_w = max(-(-ctx // ps), 1)
    p_total = b * np_w + 1
    fn = functools.partial(_paged_q8_probe_fn, ppb=ppb, interpret=interpret)
    kp_s = jax.ShapeDtypeStruct((p_total, ps, kvh, dh), jnp.int8)
    sc_s = jax.ShapeDtypeStruct((p_total, ps), jnp.float32)
    kn_s = jax.ShapeDtypeStruct((b, kvh, dh), dtype)
    args = (jax.ShapeDtypeStruct((b, kvh, g, dh), dtype), kp_s, kp_s,
            sc_s, sc_s,
            jax.ShapeDtypeStruct((b, np_w), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32), kn_s, kn_s)
    return fn, args


_PAGED_Q8_TUNE = TuneSpace(
    key=_paged_q8_sweep_key,
    candidates=lambda **f: DEFAULT_PAGED_CANDIDATES,
    vmem=_paged_q8_vmem,
    probe=_paged_q8_probe,
    default=lambda *, page_size, **f: (page_size, DEFAULT_PAGES_PER_BLOCK),
    lookup_key=_paged_q8_lookup_key,
    record_keys=_paged_q8_record_keys,
    neighbors=_paged_neighbors,
)


def _paged_heuristic(*, backend: Optional[str] = None,
                     quantized: bool = False, **_facts) -> str:
    if quantized:
        return ("pallas_paged_q8" if _backend(backend) == "tpu"
                else "jnp_paged_q8")
    return "pallas_paged" if _backend(backend) == "tpu" else "jnp_paged"


register_family("paged_decode", heuristic=_paged_heuristic,
                layout=_PAGED_LAYOUT)


def _paged_ctx_fact(page_table, k_pages) -> int:
    """Static context capacity of a dispatch site: table width x page
    size (the live length is traced; capacity is the trace-time bound)."""
    return page_table.shape[1] * k_pages.shape[1]


@register_impl("paged_decode", "pallas_paged", tune=_PAGED_TUNE,
               layout=_PAGED_LAYOUT, oracle="repro.kernels.ref.paged_decode",
               # the table-walking kernel needs a whole kv-head slice per
               # device (per_device_heads=0 = indivisible head sharding)
               supports=lambda quantized=False, per_device_heads=None, **f:
                   not quantized and (per_device_heads is None
                                      or per_device_heads >= 1))
def _run_pallas_paged(q, k_pages, v_pages, page_table, length, k_new, v_new,
                      *, pages_per_block: Optional[int] = None,
                      interpret: Optional[bool] = None):
    """Pallas paged decode kernel — bytes/token O(length), table-walked."""
    from repro.kernels.paged_decode import paged_decode_attention
    ppb = pages_per_block or best(
        "paged_decode", b=q.shape[0], kvh=k_pages.shape[2],
        g=q.shape[2] // k_pages.shape[2], dh=q.shape[-1],
        page_size=k_pages.shape[1], ctx=_paged_ctx_fact(page_table, k_pages),
        dtype=q.dtype)[1]
    return paged_decode_attention(q, k_pages, v_pages, page_table, length,
                                  k_new, v_new, pages_per_block=ppb,
                                  interpret=interpret)


@register_impl("paged_decode", "jnp_paged", layout=_PAGED_LAYOUT,
               oracle="repro.kernels.ref.paged_decode",
               supports=lambda quantized=False, **f: not quantized)
def _run_jnp_paged(q, k_pages, v_pages, page_table, length, k_new, v_new,
                   *, pages_per_block=None, interpret=None):
    """gather-based masked-dense reference (oracle/fallback)."""
    from repro.models.attention import paged_decode_jnp
    return paged_decode_jnp(q, k_pages, v_pages, page_table, length,
                            k_new, v_new)


@register_impl("paged_decode", "pallas_paged_q8", tune=_PAGED_Q8_TUNE,
               layout=_PAGED_Q8_LAYOUT,
               oracle="repro.kernels.ref.paged_decode_q8",
               supports=lambda quantized=False, per_device_heads=None, **f:
                   quantized and (per_device_heads is None
                                  or per_device_heads >= 1))
def _run_pallas_paged_q8(q, k_pages, v_pages, page_table, length, k_new,
                         v_new, *, k_scale, v_scale,
                         pages_per_block: Optional[int] = None,
                         interpret: Optional[bool] = None):
    """Pallas paged decode over int8 pages — dequant in VMEM post-DMA."""
    from repro.kernels.paged_decode import paged_decode_attention_q8
    ppb = pages_per_block or best(
        "paged_decode", impl="pallas_paged_q8",
        b=q.shape[0], kvh=k_pages.shape[2],
        g=q.shape[2] // k_pages.shape[2], dh=q.shape[-1],
        page_size=k_pages.shape[1], ctx=_paged_ctx_fact(page_table, k_pages),
        dtype=q.dtype)[1]
    return paged_decode_attention_q8(q, k_pages, v_pages, page_table,
                                     length, k_new, v_new, k_scale=k_scale,
                                     v_scale=v_scale, pages_per_block=ppb,
                                     interpret=interpret)


@register_impl("paged_decode", "jnp_paged_q8", layout=_PAGED_Q8_LAYOUT,
               oracle="repro.kernels.ref.paged_decode_q8",
               supports=lambda quantized=False, **f: quantized)
def _run_jnp_paged_q8(q, k_pages, v_pages, page_table, length, k_new, v_new,
                      *, k_scale, v_scale, pages_per_block=None,
                      interpret=None):
    """gather + dequantize masked-dense reference for the int8 pages."""
    from repro.models.attention import paged_decode_jnp
    return paged_decode_jnp(q, k_pages, v_pages, page_table, length,
                            k_new, v_new, k_scale=k_scale, v_scale=v_scale)


# ===========================================================================
# family: stream_triad (paper case study 1, §III)
# ===========================================================================

DEFAULT_BLOCK_ROWS = 256
LANES = 128

_TRIAD_BLOCK_ROWS: Tuple[int, ...] = (64, 128, 256, 512, 1024)


def triad_tune_key(*, n: int, dtype, backend: Optional[str] = None,
                   **_ignored) -> str:
    return f"triad-n{n}-{_dtype_name(dtype)}-{_backend(backend)}"


def _triad_candidates(*, n: int, **facts) -> Tuple[Tuple[int], ...]:
    rows = max(n // LANES, 1)
    cands = tuple((br,) for br in _TRIAD_BLOCK_ROWS if br <= rows)
    return cands or ((rows,),)


def _triad_vmem(cand, itemsize, **facts) -> int:
    (br,) = cand
    # b + c streams double-buffered in, a double-buffered out
    return 2 * (2 * br * LANES + br * LANES) * itemsize


def _triad_probe_fn(b, c, *, s: float, block_rows: int, interpret: bool):
    """Module-level probe target for the triad block_rows sweep."""
    from repro.kernels.stream_triad import stream_triad
    return stream_triad(b, c, s=s, block_rows=block_rows,
                        interpret=interpret, pipelined=True)


def _triad_probe(cand, interpret, *, n, dtype, **facts):
    (br,) = cand
    fn = functools.partial(_triad_probe_fn, s=2.5, block_rows=br,
                           interpret=interpret)
    x = jax.ShapeDtypeStruct((n,), dtype)
    return fn, (x, x)


_TRIAD_TUNE = TuneSpace(
    key=triad_tune_key,
    candidates=_triad_candidates,
    vmem=_triad_vmem,
    probe=_triad_probe,
    default=(DEFAULT_BLOCK_ROWS,),
)

_TRIAD_LAYOUT = "b, c: flat [N] (N % 128 == 0) -> a = b + s*c"


def _triad_heuristic(*, backend: Optional[str] = None, **_facts) -> str:
    return "pallas_triad" if _backend(backend) == "tpu" else "xla_triad"


register_family("stream_triad", heuristic=_triad_heuristic,
                layout=_TRIAD_LAYOUT)


@register_impl("stream_triad", "pallas_triad", tune=_TRIAD_TUNE,
               layout=_TRIAD_LAYOUT, oracle="repro.kernels.ref.stream_triad")
def _run_pallas_triad(b, c, *, s: float = 2.5,
                      block_rows: Optional[int] = None,
                      interpret: Optional[bool] = None,
                      pipelined: bool = True):
    """Pallas tiled triad — the double-buffered HBM-stream case study."""
    from repro.kernels.stream_triad import stream_triad
    if interpret is None:
        interpret = default_interpret()
    br = block_rows or best("stream_triad", n=b.shape[0], dtype=b.dtype)[0]
    return stream_triad(b, c, s=s, block_rows=br, interpret=interpret,
                        pipelined=pipelined)


@register_impl("stream_triad", "xla_triad", layout=_TRIAD_LAYOUT,
               oracle="repro.kernels.ref.stream_triad")
def _run_xla_triad(b, c, *, s: float = 2.5, block_rows=None, interpret=None,
                   pipelined: bool = True):
    """plain XLA fused elementwise (the non-Pallas baseline)."""
    return b + s * c


# ===========================================================================
# family: jacobi7 (paper case studies 2+3, §IV-§V)
# ===========================================================================

DEFAULT_BLOCK_X = 8

_JACOBI_BLOCK_X: Tuple[int, ...] = (4, 8, 16, 32)


def jacobi_tune_key(*, shape: Tuple[int, int, int], sweeps: int, dtype,
                    backend: Optional[str] = None, **_ignored) -> str:
    x, y, z = shape
    return (f"jacobi7-x{x}y{y}z{z}t{sweeps}"
            f"-{_dtype_name(dtype)}-{_backend(backend)}")


def _jacobi_candidates(*, shape, sweeps, **facts) -> Tuple[Tuple[int], ...]:
    ox = shape[0] - 2 * sweeps
    cands = tuple((bx,) for bx in _JACOBI_BLOCK_X if bx <= ox)
    return cands or ((max(ox, 1),),)


def _jacobi_vmem(cand, itemsize, *, shape, sweeps, **facts) -> int:
    from repro.kernels.jacobi7 import vmem_footprint
    (bx,) = cand
    return vmem_footprint(tuple(shape), sweeps, bx, itemsize)


def _jacobi_probe_fn(x, *, sweeps: int, block_x: int, interpret: bool):
    """Module-level probe target for the jacobi7 block_x sweep."""
    from repro.kernels.jacobi7 import jacobi7_wavefront
    return jacobi7_wavefront(x, sweeps=sweeps, block_x=block_x,
                             interpret=interpret)


def _jacobi_probe(cand, interpret, *, shape, sweeps, dtype, **facts):
    (bx,) = cand
    fn = functools.partial(_jacobi_probe_fn, sweeps=sweeps, block_x=bx,
                           interpret=interpret)
    return fn, (jax.ShapeDtypeStruct(tuple(shape), dtype),)


_JACOBI_TUNE = TuneSpace(
    key=jacobi_tune_key,
    candidates=_jacobi_candidates,
    vmem=_jacobi_vmem,
    probe=_jacobi_probe,
    default=(DEFAULT_BLOCK_X,),
)

_JACOBI_LAYOUT = "x [X,Y,Z] -> [X-2T,Y-2T,Z-2T] (T valid-mode sweeps)"


def _jacobi_heuristic(**_facts) -> str:
    # the wavefront variant IS the paper's point (T sweeps per VMEM
    # residency); naive is the per-sweep-round-trip baseline
    return "wavefront"


register_family("jacobi7", heuristic=_jacobi_heuristic,
                layout=_JACOBI_LAYOUT)


@register_impl("jacobi7", "wavefront", tune=_JACOBI_TUNE,
               layout=_JACOBI_LAYOUT, oracle="repro.kernels.ref.jacobi7_valid")
def _run_jacobi_wavefront(x, *, sweeps: int = 1, omega: float = 1.0 / 6.0,
                          block_x: Optional[int] = None,
                          interpret: Optional[bool] = None):
    """T sweeps per VMEM residency (~1 HBM round-trip total)."""
    from repro.kernels.jacobi7 import jacobi7_wavefront
    if interpret is None:
        interpret = default_interpret()
    bx = block_x or best("jacobi7", shape=tuple(x.shape), sweeps=sweeps,
                         dtype=x.dtype)[0]
    return jacobi7_wavefront(x, sweeps=sweeps, omega=omega, block_x=bx,
                             interpret=interpret)


@register_impl("jacobi7", "naive", layout=_JACOBI_LAYOUT,
               oracle="repro.kernels.ref.jacobi7_valid")
def _run_jacobi_naive(x, *, sweeps: int = 1, omega: float = 1.0 / 6.0,
                      block_x: Optional[int] = None,
                      interpret: Optional[bool] = None):
    """one sweep per call — T sweeps cost T full HBM round-trips."""
    from repro.kernels.jacobi7 import jacobi7_naive
    if interpret is None:
        interpret = default_interpret()
    bx = block_x or DEFAULT_BLOCK_X
    for _ in range(sweeps):
        x = jacobi7_naive(x, omega=omega, block_x=bx, interpret=interpret)
    return x


# ===========================================================================
# family: ssd_scan (mLSTM / Mamba2 chunked gated linear attention)
# ===========================================================================

DEFAULT_SSD_CHUNK = 128

_SSD_CHUNKS: Tuple[int, ...] = (32, 64, 128, 256)


def ssd_tune_key(*, b: int, s: int, h: int, dk: int, dv: int,
                 normalize: bool = False, dtype,
                 backend: Optional[str] = None, **_ignored) -> str:
    return (f"ssd-b{b}s{s}h{h}dk{dk}dv{dv}"
            f"-{'norm' if normalize else 'raw'}"
            f"-{_dtype_name(dtype)}-{_backend(backend)}")


def _ssd_candidates(*, s: int, **facts) -> Tuple[Tuple[int], ...]:
    cands = tuple((c,) for c in _SSD_CHUNKS if c <= s)
    return cands or ((s,),)


def _ssd_vmem(cand, itemsize, *, dk, dv, **facts) -> int:
    (c,) = cand
    # q/k [c,dk] + v/y [c,dv] double-buffered; [c,c] score tile + C/n
    # state live once in f32 scratch
    io = 2 * (2 * c * dk + 2 * c * dv + 2 * c) * itemsize
    compute = (c * c + dk * dv + dk) * 4
    return io + compute


def _ssd_probe_fn(q, k, v, lf, li, *, chunk: int, normalize: bool,
                  interpret: bool):
    """Module-level probe target for the ssd chunk sweep."""
    from repro.kernels.ssd_scan import ssd_scan_flat
    return ssd_scan_flat(q, k, v, lf, li, chunk=chunk, normalize=normalize,
                         interpret=interpret)


def _ssd_probe(cand, interpret, *, b, s, h, dk, dv, dtype,
               normalize=False, **facts):
    (c,) = cand
    fn = functools.partial(_ssd_probe_fn, chunk=c, normalize=normalize,
                           interpret=interpret)
    bh = b * h
    gates = jax.ShapeDtypeStruct((bh, s), dtype)
    args = (jax.ShapeDtypeStruct((bh, s, dk), dtype),
            jax.ShapeDtypeStruct((bh, s, dk), dtype),
            jax.ShapeDtypeStruct((bh, s, dv), dtype), gates, gates)
    return fn, args


def _ssd_neighbors(*, b: int, s: int, **_facts) -> List[Dict[str, Any]]:
    """Nearby tuned buckets for the chunk sweep: batch first (chunk is a
    per-row property), then sequence length by pow2 steps (the chunked
    scan clamps chunk to min(chunk, s), so an adopted larger chunk
    stays valid for shorter sequences)."""
    out: List[Dict[str, Any]] = []
    for f in (2, 4):
        if b // f >= 1:
            out.append({"b": b // f})
        out.append({"b": b * f})
    for f in (2, 4):
        if s // f >= 1:
            out.append({"s": s // f})
        out.append({"s": s * f})
    return out


_SSD_TUNE = TuneSpace(
    key=ssd_tune_key,
    candidates=_ssd_candidates,
    vmem=_ssd_vmem,
    probe=_ssd_probe,
    default=(DEFAULT_SSD_CHUNK,),
    neighbors=_ssd_neighbors,
)

_SSD_LAYOUT = ("q,k [B,S,H,dk]; v [B,S,H,dv]; log_f/log_i [B,S,H] (<=0) "
               "-> (y [B,S,H,dv], (C [B,H,dk,dv], n [B,H,dk]))")


def _ssd_heuristic(*, backend: Optional[str] = None, **_facts) -> str:
    return "pallas_ssd" if _backend(backend) == "tpu" else "jnp_scan"


def _ssd_facts(q, k, v, log_f, log_i, **_kw) -> Dict[str, Any]:
    del k, v, log_f, log_i
    return {}


register_family("ssd_scan", heuristic=_ssd_heuristic, facts=_ssd_facts,
                layout=_SSD_LAYOUT)


def _ssd_chunk(q, v, chunk: Optional[int], normalize: bool) -> int:
    if chunk is not None:
        return chunk
    b, s, h, dk = q.shape
    return best("ssd_scan", b=b, s=s, h=h, dk=dk, dv=v.shape[-1],
                normalize=normalize, dtype=q.dtype)[0]


@register_impl("ssd_scan", "pallas_ssd", tune=_SSD_TUNE,
               layout=_SSD_LAYOUT, oracle="repro.kernels.ref.ssd_scan")
def _run_pallas_ssd(q, k, v, log_f, log_i, *, chunk: Optional[int] = None,
                    normalize: bool = False,
                    interpret: Optional[bool] = None):
    """Pallas SSD blocked scan — state persists in VMEM across chunks."""
    from repro.kernels import ops
    return ops.ssd_scan(q, k, v, log_f, log_i,
                        chunk=_ssd_chunk(q, v, chunk, normalize),
                        normalize=normalize, interpret=interpret)


@register_impl("ssd_scan", "jnp_scan", layout=_SSD_LAYOUT,
               oracle="repro.kernels.ref.ssd_scan")
def _run_jnp_ssd(q, k, v, log_f, log_i, *, chunk: Optional[int] = None,
                 normalize: bool = False, interpret: Optional[bool] = None):
    """chunk-parallel jnp twin (training-safe, the grad path)."""
    from repro.models.linear_scan import _chunked_linear_attention
    return _chunked_linear_attention(q, k, v, log_f, log_i,
                                     chunk_size=_ssd_chunk(q, v, chunk,
                                                           normalize),
                                     normalize=normalize)


# ===========================================================================
# family: sampling (greedy / top-k / top-p) — registered by its own module
# ===========================================================================

from repro.kernels import sampling  # noqa: E402,F401  (registration side-effect)
