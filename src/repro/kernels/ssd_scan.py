"""Chunked gated linear attention kernel (Pallas) — mLSTM / Mamba2 SSD.

Implements the contract of
:func:`repro.models.linear_scan.chunked_linear_attention` on a
(B*H, chunks) grid with the chunk dimension innermost: the inter-chunk
state C [dk,dv] and normalizer n [1,dk] persist in VMEM scratch across
chunk iterations (the recurrence), while the intra-chunk term is a pair of
MXU matmuls over the [c,c] decay-masked score tile — the SSD blocked
algorithm mapped to TPU (DESIGN.md §2).

Stability contract: log_f <= 0 and log_i <= 0 (enforced upstream by
log-sigmoid gates / dt folding), so every exponent is <= 0 and no running-
max stabilizer state is needed.

Oracle: kernels/ref.py::ssd_scan (sequential scan).

Registered as the ``ssd_scan`` family in kernels/registry.py
(``pallas_ssd`` — this kernel via ops.ssd_scan — vs the chunk-parallel
``jnp_scan`` twin); the chunk length is its tune space.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_flat"]


def _ssd_kernel(q_ref, k_ref, v_ref, lf_ref, li_ref, y_ref, c_out_ref,
                n_out_ref, C_ref, n_ref, *, c: int, normalize: bool,
                eps: float):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    q = q_ref[...].astype(jnp.float32)              # [c, dk]
    k = k_ref[...].astype(jnp.float32)              # [c, dk]
    v = v_ref[...].astype(jnp.float32)              # [c, dv]
    lf = lf_ref[...].astype(jnp.float32)[0]         # [c]
    li = li_ref[...].astype(jnp.float32)[0]         # [c]

    Bc = jnp.cumsum(lf)                             # [c]
    total = Bc[-1]

    # inter-chunk: contribution of the carried state
    qd = q * jnp.exp(Bc)[:, None]                   # [c, dk]
    y_inter = jax.lax.dot(qd, C_ref[...])           # [c, dv]
    n_inter = jax.lax.dot(qd, n_ref[...].T)[:, 0]   # [c]

    # intra-chunk: decay-masked attention
    gap = Bc[:, None] - Bc[None, :] + li[None, :]   # [c, c]
    tri = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    A = jnp.where(tri, jnp.exp(gap), 0.0)
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * A
    y = y_inter + jax.lax.dot(scores, v)
    if normalize:
        denom = jnp.abs(n_inter + jnp.sum(scores, axis=1))
        y = y / jnp.maximum(denom, eps)[:, None]
    y_ref[...] = y.astype(y_ref.dtype)

    # state update
    wj = jnp.exp(total - Bc + li)                   # [c]
    kw = k * wj[:, None]                            # [c, dk]
    C_ref[...] = jnp.exp(total) * C_ref[...] + \
        jax.lax.dot_general(kw, v, (((0,), (0,)), ((), ())))
    n_ref[...] = jnp.exp(total) * n_ref[...] + \
        jnp.sum(kw, axis=0, keepdims=True)

    @pl.when(j == nj - 1)
    def _finish():
        c_out_ref[...] = C_ref[...]
        n_out_ref[...] = n_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "normalize", "eps",
                                             "interpret"))
def ssd_scan_flat(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  log_f: jnp.ndarray, log_i: jnp.ndarray, *,
                  chunk: int = 128, normalize: bool = False,
                  eps: float = 1e-6, interpret: bool = True
                  ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Flat layout: q,k [BH,S,dk]; v [BH,S,dv]; log_f/log_i [BH,S].

    Returns (y [BH,S,dv], (C [BH,dk,dv], n [BH,1,dk])).
    S is padded to a chunk multiple with log_i = -1e9 (inert writes).
    """
    bh, s, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = zp(q), zp(k), zp(v)
        log_f = zp(log_f)
        log_i = jnp.pad(log_i, ((0, 0), (0, pad)), constant_values=-1e9)
    nc = q.shape[1] // c
    # gates as [BH, 1, S]-style blocks: keep 2D block (1, c) on [BH, S]
    y, c_out, n_out = pl.pallas_call(
        functools.partial(_ssd_kernel, c=c, normalize=normalize, eps=eps),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((None, c, dk), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, c, dk), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, c, dv), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c), lambda b, j: (b, j)),
            pl.BlockSpec((1, c), lambda b, j: (b, j)),
        ],
        out_specs=[
            pl.BlockSpec((None, c, dv), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, dk, dv), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((None, 1, dk), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, nc * c, dv), v.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, 1, dk), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((1, dk), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, log_f, log_i)
    return y[:, :s], (c_out, n_out)
