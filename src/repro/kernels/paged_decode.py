"""Paged decode attention (Pallas, TPU-targeted): O(length) bytes/token.

The decode hot path used to score the ENTIRE [B, max_seq, KVH, Dh] cache
buffer every token and mask — bytes/token was O(max_seq) even for rows
holding 30 tokens of context.  This kernel walks each row's *page table*
instead: the KV cache lives in a pool of fixed-size pages
(``serve/kv_pool.py``), each row owns exactly ``ceil(length / page_size)``
of them, and decode touches only those.

Structure (grid = batch x kv-heads x page-blocks, page-blocks innermost):

* the page table ``[B, NP]`` and per-row lengths ``[B]`` are scalar-
  prefetched (``pltpu.PrefetchScalarGridSpec``), so the k/v BlockSpec
  index maps translate *logical* page j of row b to its *physical* page
  ``pt[b, j]`` before the DMA is issued — the gather happens in the
  pipeline, no materialized gathered copy;
* dead logical pages (``j * page_size >= length[b]``) clamp their index
  map to the row's last live page — consecutive grid steps then request
  the SAME block, which the pipeline does not re-fetch — and skip their
  matmuls entirely via ``pl.when``;
* online softmax state (running max / denominator / accumulator) lives in
  VMEM scratch across the page-block iterations; at the last block the
  NEW token's K/V (one [KVH, Dh] row, passed separately so the caller can
  scatter it into its page afterwards) is folded into the same softmax
  and the output normalized — the exact two-part-softmax contract of
  ``models/attention.py::decode_attention_token``;
* ``pages_per_block`` fetches that many pages per grid step (each its own
  BlockSpec, so non-contiguous physical pages still pipeline); together
  with ``page_size`` it is the tile knob ``kernels/autotune.py`` sweeps.

Layout contract: q grouped [B, KVH, G, Dh]; pages [P, page_size, KVH, Dh]
(the pool layout, one layer's slice).  ``paged_decode_attention`` adapts
from the model's [B, 1, H, Dh].  Oracle: kernels/ref.py::paged_decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_decode_attention", "paged_decode_attention_grouped",
           "paged_decode_attention_q8", "paged_decode_attention_q8_grouped"]

NEG_INF = -2.0e38


def _paged_kernel(lens_ref, pt_ref, q_ref, *refs,
                  scale: float, ps: int, ppb: int):
    """refs: k_0..k_{ppb-1}, v_0..v_{ppb-1}, k_new, v_new, o, m, l, acc."""
    k_refs = refs[:ppb]
    v_refs = refs[ppb:2 * ppb]
    kn_ref, vn_ref, o_ref, m_ref, l_ref, acc_ref = refs[2 * ppb:]
    b = pl.program_id(0)
    j = pl.program_id(2)                  # page block (innermost, sequential)
    njb = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lens_ref[b]                  # this row's past-token count
    q = q_ref[...].astype(jnp.float32) * scale            # [G, Dh]

    for i in range(ppb):
        p = j * ppb + i                   # logical page index

        # dead pages (entirely past this row's context) skip both matmuls;
        # their index map already clamps to a live page, so no new DMA
        # was issued for them either
        @pl.when(p * ps < length)
        def _accumulate(i=i, p=p):
            k = k_refs[i][...].astype(jnp.float32)        # [ps, Dh]
            v = v_refs[i][...].astype(jnp.float32)        # [ps, Dh]
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
            kpos = p * ps + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            ok = kpos < length            # partial last page
            s = jnp.where(ok, s, NEG_INF)
            m_prev = m_ref[...]                           # [G, 1]
            m_new = jnp.maximum(m_prev,
                                jnp.max(s, axis=1, keepdims=True))
            alpha = jnp.exp(m_prev - m_new)
            pr = jnp.exp(s - m_new)
            pr = jnp.where(ok, pr, 0.0)
            l_ref[...] = l_ref[...] * alpha + jnp.sum(pr, axis=1,
                                                      keepdims=True)
            acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(pr, v)
            m_ref[...] = m_new

    @pl.when(j == njb - 1)
    def _fold_token_and_finish():
        # the new token attends itself: fold its single K/V row into the
        # running softmax, then normalize — rows with length == 0 (empty
        # slots) come through here with (m, l, acc) untouched and output
        # exactly softmax over {the token} = v_new
        kt = kn_ref[...].astype(jnp.float32)              # [1, Dh]
        vt = vn_ref[...].astype(jnp.float32)              # [1, Dh]
        s_t = jax.lax.dot_general(q, kt, (((1,), (1,)), ((), ())))  # [G, 1]
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s_t)
        alpha = jnp.exp(m_prev - m_new)
        p_t = jnp.exp(s_t - m_new)
        l = l_ref[...] * alpha + p_t
        acc = acc_ref[...] * alpha + p_t * vt
        o_ref[...] = (acc / jnp.maximum(l, 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("pages_per_block", "interpret"))
def paged_decode_attention_grouped(q4: jnp.ndarray, k_pages: jnp.ndarray,
                                   v_pages: jnp.ndarray,
                                   page_table: jnp.ndarray,
                                   lengths: jnp.ndarray,
                                   k_new: jnp.ndarray, v_new: jnp.ndarray, *,
                                   pages_per_block: int = 1,
                                   interpret: bool | None = None
                                   ) -> jnp.ndarray:
    """q4: [B,KVH,G,Dh]; k/v_pages: [P,ps,KVH,Dh]; page_table: [B,NP] int32;
    lengths: [B] int32 (past tokens; the new token is NOT in the pages yet);
    k_new/v_new: [B,KVH,Dh].  Returns [B,KVH,G,Dh].

    ``page_table[b, j]`` is the physical page holding row b's tokens
    ``[j*ps, (j+1)*ps)``; entries past ``ceil(lengths[b]/ps)`` are never
    read (their index maps clamp to the last live page, their compute is
    skipped).  Physical page 0 is the pool's null page by convention —
    rows with ``lengths[b] == 0`` resolve to it but accumulate nothing.
    """
    if interpret is None:
        from repro.kernels.registry import default_interpret
        interpret = default_interpret()
    b, kvh, g, dh = q4.shape
    p_total, ps, kvh_p, _ = k_pages.shape
    assert kvh_p == kvh, (kvh_p, kvh)
    np_w = page_table.shape[1]
    ppb = max(1, min(pages_per_block, np_w))
    njb = -(-np_w // ppb)
    scale = 1.0 / (dh ** 0.5)
    lengths = jnp.asarray(lengths, jnp.int32)
    page_table = jnp.asarray(page_table, jnp.int32)
    kn = k_new.reshape(b, kvh, 1, dh)
    vn = v_new.reshape(b, kvh, 1, dh)

    def page_map(i):
        # logical page j*ppb+i of row b -> physical page, clamped to the
        # row's last LIVE page so dead grid steps re-request the block
        # already resident (the pipeline elides the copy)
        def imap(b_, h_, j_, lens, pt):
            p_log = j_ * ppb + i
            live = jnp.maximum((lens[b_] + ps - 1) // ps - 1, 0)
            p_eff = jnp.minimum(jnp.minimum(p_log, np_w - 1), live)
            return (pt[b_, p_eff], 0, h_, 0)
        return imap

    kv_specs = [pl.BlockSpec((None, ps, None, dh), page_map(i))
                for i in range(ppb)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,            # lengths, page_table
        grid=(b, kvh, njb),
        in_specs=[
            pl.BlockSpec((None, None, g, dh),
                         lambda b_, h_, j_, lens, pt: (b_, h_, 0, 0)),
            *kv_specs,                    # k pages
            *kv_specs,                    # v pages (same maps)
            pl.BlockSpec((None, None, 1, dh),
                         lambda b_, h_, j_, lens, pt: (b_, h_, 0, 0)),
            pl.BlockSpec((None, None, 1, dh),
                         lambda b_, h_, j_, lens, pt: (b_, h_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, g, dh),
                               lambda b_, h_, j_, lens, pt: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),      # running max
            pltpu.VMEM((g, 1), jnp.float32),      # denominator
            pltpu.VMEM((g, dh), jnp.float32),     # output accumulator
        ],
    )
    kernel = functools.partial(_paged_kernel, scale=scale, ps=ps, ppb=ppb)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, dh), q4.dtype),
        interpret=interpret,
    )(lengths, page_table,
      q4, *([k_pages] * ppb), *([v_pages] * ppb), kn, vn)


def _paged_kernel_q8(lens_ref, pt_ref, q_ref, *refs,
                     scale: float, ps: int, ppb: int):
    """int8 variant: pages hold int8 codes, dequantized RIGHT AFTER the
    DMA with the per-token-row scales that ride the same page index maps.
    refs: k_0..k_{ppb-1}, v_0.., ksc_0.., vsc_0.., k_new, v_new, o,
    m, l, acc.  The new token's K/V stay fp — it is not in a page yet.
    """
    k_refs = refs[:ppb]
    v_refs = refs[ppb:2 * ppb]
    ksc_refs = refs[2 * ppb:3 * ppb]
    vsc_refs = refs[3 * ppb:4 * ppb]
    kn_ref, vn_ref, o_ref, m_ref, l_ref, acc_ref = refs[4 * ppb:]
    b = pl.program_id(0)
    j = pl.program_id(2)
    njb = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lens_ref[b]
    q = q_ref[...].astype(jnp.float32) * scale            # [G, Dh]

    for i in range(ppb):
        p = j * ppb + i

        @pl.when(p * ps < length)
        def _accumulate(i=i, p=p):
            # dequant in VMEM: int8 codes [ps, Dh] x f32 row scales [ps, 1]
            k = k_refs[i][...].astype(jnp.float32) * ksc_refs[i][...]
            v = v_refs[i][...].astype(jnp.float32) * vsc_refs[i][...]
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
            kpos = p * ps + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            ok = kpos < length
            s = jnp.where(ok, s, NEG_INF)
            m_prev = m_ref[...]
            m_new = jnp.maximum(m_prev,
                                jnp.max(s, axis=1, keepdims=True))
            alpha = jnp.exp(m_prev - m_new)
            pr = jnp.exp(s - m_new)
            pr = jnp.where(ok, pr, 0.0)
            l_ref[...] = l_ref[...] * alpha + jnp.sum(pr, axis=1,
                                                      keepdims=True)
            acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(pr, v)
            m_ref[...] = m_new

    @pl.when(j == njb - 1)
    def _fold_token_and_finish():
        kt = kn_ref[...].astype(jnp.float32)              # [1, Dh]
        vt = vn_ref[...].astype(jnp.float32)
        s_t = jax.lax.dot_general(q, kt, (((1,), (1,)), ((), ())))
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s_t)
        alpha = jnp.exp(m_prev - m_new)
        p_t = jnp.exp(s_t - m_new)
        l = l_ref[...] * alpha + p_t
        acc = acc_ref[...] * alpha + p_t * vt
        o_ref[...] = (acc / jnp.maximum(l, 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("pages_per_block", "interpret"))
def paged_decode_attention_q8_grouped(q4: jnp.ndarray, k_pages: jnp.ndarray,
                                      v_pages: jnp.ndarray,
                                      k_scale: jnp.ndarray,
                                      v_scale: jnp.ndarray,
                                      page_table: jnp.ndarray,
                                      lengths: jnp.ndarray,
                                      k_new: jnp.ndarray,
                                      v_new: jnp.ndarray, *,
                                      pages_per_block: int = 1,
                                      interpret: bool | None = None
                                      ) -> jnp.ndarray:
    """:func:`paged_decode_attention_grouped` over int8 pages.

    k/v_pages hold int8 codes; k/v_scale ``[P, ps]`` f32 hold one dequant
    factor per resident token row.  The scales ride the SAME page index
    maps as their pages (one extra [ps] f32 vector per page DMA — ~1.5%
    of the page's int8 bytes at Dh=128), and dequantization happens in
    VMEM between the DMA and the QK^T matmul: HBM sees only int8.
    """
    if interpret is None:
        from repro.kernels.registry import default_interpret
        interpret = default_interpret()
    b, kvh, g, dh = q4.shape
    p_total, ps, kvh_p, _ = k_pages.shape
    assert kvh_p == kvh, (kvh_p, kvh)
    assert k_pages.dtype == jnp.int8, k_pages.dtype
    np_w = page_table.shape[1]
    ppb = max(1, min(pages_per_block, np_w))
    njb = -(-np_w // ppb)
    scale = 1.0 / (dh ** 0.5)
    lengths = jnp.asarray(lengths, jnp.int32)
    page_table = jnp.asarray(page_table, jnp.int32)
    kn = k_new.reshape(b, kvh, 1, dh)
    vn = v_new.reshape(b, kvh, 1, dh)
    # [P, ps] -> [P, ps, 1] so the in-kernel scale block is 2D ([ps, 1]
    # broadcasts over the page's [ps, Dh] codes)
    ksc = k_scale.astype(jnp.float32)[..., None]
    vsc = v_scale.astype(jnp.float32)[..., None]

    def page_map(i):
        def imap(b_, h_, j_, lens, pt):
            p_log = j_ * ppb + i
            live = jnp.maximum((lens[b_] + ps - 1) // ps - 1, 0)
            p_eff = jnp.minimum(jnp.minimum(p_log, np_w - 1), live)
            return (pt[b_, p_eff], 0, h_, 0)
        return imap

    def scale_map(i):
        def imap(b_, h_, j_, lens, pt):
            p_log = j_ * ppb + i
            live = jnp.maximum((lens[b_] + ps - 1) // ps - 1, 0)
            p_eff = jnp.minimum(jnp.minimum(p_log, np_w - 1), live)
            return (pt[b_, p_eff], 0, 0)
        return imap

    kv_specs = [pl.BlockSpec((None, ps, None, dh), page_map(i))
                for i in range(ppb)]
    sc_specs = [pl.BlockSpec((None, ps, 1), scale_map(i))
                for i in range(ppb)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,            # lengths, page_table
        grid=(b, kvh, njb),
        in_specs=[
            pl.BlockSpec((None, None, g, dh),
                         lambda b_, h_, j_, lens, pt: (b_, h_, 0, 0)),
            *kv_specs,                    # k pages (int8)
            *kv_specs,                    # v pages (int8)
            *sc_specs,                    # k scales
            *sc_specs,                    # v scales
            pl.BlockSpec((None, None, 1, dh),
                         lambda b_, h_, j_, lens, pt: (b_, h_, 0, 0)),
            pl.BlockSpec((None, None, 1, dh),
                         lambda b_, h_, j_, lens, pt: (b_, h_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, g, dh),
                               lambda b_, h_, j_, lens, pt: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_kernel_q8, scale=scale, ps=ps, ppb=ppb)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, dh), q4.dtype),
        interpret=interpret,
    )(lengths, page_table,
      q4, *([k_pages] * ppb), *([v_pages] * ppb),
      *([ksc] * ppb), *([vsc] * ppb), kn, vn)


def paged_decode_attention_q8(q: jnp.ndarray, k_pages: jnp.ndarray,
                              v_pages: jnp.ndarray, page_table: jnp.ndarray,
                              lengths: jnp.ndarray, k_new: jnp.ndarray,
                              v_new: jnp.ndarray, *,
                              k_scale: jnp.ndarray, v_scale: jnp.ndarray,
                              pages_per_block: int = 1,
                              interpret: bool | None = None) -> jnp.ndarray:
    """Model layout int8 entry: q [B,1,H,Dh], k/v_new [B,1,KVH,Dh],
    int8 pages + [P, ps] scales -> [B,1,H,Dh]."""
    b, _, h, dh = q.shape
    kvh = k_pages.shape[2]
    g = h // kvh
    q4 = q.reshape(b, kvh, g, dh)
    out = paged_decode_attention_q8_grouped(
        q4, k_pages, v_pages, k_scale, v_scale, page_table, lengths,
        k_new.reshape(b, kvh, dh), v_new.reshape(b, kvh, dh),
        pages_per_block=pages_per_block, interpret=interpret)
    return out.reshape(b, 1, h, dh)


def paged_decode_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, page_table: jnp.ndarray,
                           lengths: jnp.ndarray, k_new: jnp.ndarray,
                           v_new: jnp.ndarray, *,
                           pages_per_block: int = 1,
                           interpret: bool | None = None) -> jnp.ndarray:
    """Model layout: q [B,1,H,Dh], k_new/v_new [B,1,KVH,Dh] -> [B,1,H,Dh]."""
    b, _, h, dh = q.shape
    kvh = k_pages.shape[2]
    g = h // kvh
    q4 = q.reshape(b, kvh, g, dh)
    out = paged_decode_attention_grouped(
        q4, k_pages, v_pages, page_table, lengths,
        k_new.reshape(b, kvh, dh), v_new.reshape(b, kvh, dh),
        pages_per_block=pages_per_block, interpret=interpret)
    return out.reshape(b, 1, h, dh)
