"""DEPRECATED kernel dispatch + autotune surface — one compatibility module.

PR 3 grew ``kernels/dispatch.py`` (the attention ladder), PR 4 grew it a
paged-decode twin plus ``kernels/autotune.py`` (two sweep functions, two
process-local winner dicts); PR 5 replaced all of it with the one
registry (:mod:`repro.kernels.registry`).  This module is the single
remaining shim: every legacy symbol lives here with its EXACT historical
semantics, emits a :class:`DeprecationWarning` naming its registry
replacement (once per symbol per process), and ``dispatch.py`` /
``autotune.py`` are two-line re-export stubs over it.

Migration table (legacy -> registry)::

    select_attention_impl(...)       registry.select("attention", ...)
    run_attention(name, ...)         registry.run("attention", ..., impl=name)
    select_paged_decode_impl(...)    registry.select("paged_decode", ...)
    run_paged_decode(name, ...)      registry.run("paged_decode", ..., impl=name)
    use_attention_impl(name)         registry.use_impl(**LEGACY_ATTN_MAP[name])
    attention_impl_override()        registry.override_for(family)
    autotune_flash_blocks(...)       registry.autotune("attention", session, ...)
    autotune_paged_decode(...)       registry.autotune("paged_decode", session, ...)
    best_blocks(...)                 registry.best("attention", ...)
    best_paged_block(...)            registry.best("paged_decode", ...)[1]
    record_blocks(key, bq, bk)       registry.record("attention", key, (bq, bk))
    clear_table()                    registry.clear_tune_table()
    tune_key(...)                    registry.attention_tune_key(...)
    paged_tune_key(...)              registry.paged_lookup_key(...)
    vmem_footprint(...)              registry.attention_vmem(...)
    paged_vmem_footprint(...)        registry.paged_vmem(...)
    $REPRO_ATTN_IMPL=name            $REPRO_IMPL=attention=...,paged_decode=...
    ServeConfig(attn_impl=name)      ServeConfig(impls={family: impl, ...})

Semantics preserved exactly: ``use_attention_impl`` expands single names
through ``LEGACY_ATTN_MAP`` onto the attention AND paged_decode families
(``"paged_decode"`` pins the decode side only), ``run_attention``
rejects ``"paged_decode"`` with the historical message, warm autotune
calls return the persisted record with zero sweeps and zero lowerings.
"""

from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core import hwinfo
from repro.kernels import registry
from repro.kernels.registry import (DEFAULT_BLOCKS, DEFAULT_CANDIDATES,
                                    DEFAULT_PAGED_CANDIDATES,
                                    DEFAULT_PAGES_PER_BLOCK,
                                    default_interpret)

__all__ = [
    # dispatch surface
    "ATTENTION_IMPLS", "OVERRIDE_IMPLS", "PAGED_DECODE_IMPLS",
    "default_interpret", "select_attention_impl", "use_attention_impl",
    "attention_impl_override", "run_attention", "select_paged_decode_impl",
    "run_paged_decode",
    # autotune surface
    "DEFAULT_BLOCKS", "DEFAULT_CANDIDATES", "TuneRecord", "vmem_footprint",
    "tune_key", "autotune_flash_blocks", "best_blocks", "record_blocks",
    "clear_table", "DEFAULT_PAGES_PER_BLOCK", "DEFAULT_PAGED_CANDIDATES",
    "PagedTuneRecord", "paged_tune_key", "paged_vmem_footprint",
    "autotune_paged_decode", "best_paged_block",
]

ATTENTION_IMPLS = ("pallas_flash", "jnp_flash", "full")

#: the two concrete paged decode-attention implementations (selected by
#: :func:`select_paged_decode_impl`; ``paged_decode`` in the override
#: ladder forces the Pallas kernel)
PAGED_DECODE_IMPLS = ("pallas_paged", "jnp_paged")

#: names accepted by the LEGACY override ladder (use_attention_impl /
#: $REPRO_ATTN_IMPL / ServeConfig.attn_impl).  ``paged_decode`` pins the
#: DECODE side to the Pallas paged kernel and is transparent to prefill
#: selection (prefill falls through to heuristics).
OVERRIDE_IMPLS = ATTENTION_IMPLS + ("paged_decode",)


_WARNED: set = set()


def _deprecated(symbol: str, replacement: str,
                module: str = "repro.kernels.legacy") -> None:
    """One DeprecationWarning per (module, symbol) per process.

    Keyed per symbol — NOT once per process — so migration surfaces
    every distinct legacy call site (these shims sit on trace-time hot
    paths, hence the dedup at all); keyed per module too, so reaching
    ``use_attention_impl`` through ``kernels.dispatch`` and through
    ``kernels.legacy`` names both spellings."""
    if (module, symbol) in _WARNED:
        return
    _WARNED.add((module, symbol))
    warnings.warn(
        f"{module}.{symbol} is deprecated; use {replacement}",
        DeprecationWarning, stacklevel=3)


#: replacement named in the warning when a symbol is reached through the
#: ``dispatch.py`` / ``autotune.py`` module stubs (the function shims
#: below warn with the same strings when CALLED; this table also covers
#: the constants, which the call-time shims can never warn for)
_STUB_REPLACEMENTS: Dict[str, str] = {
    "ATTENTION_IMPLS": 'registry.impls("attention")',
    "PAGED_DECODE_IMPLS": 'registry.impls("paged_decode")',
    "OVERRIDE_IMPLS": "registry.LEGACY_ATTN_MAP",
    "default_interpret": "registry.default_interpret",
    "select_attention_impl": 'registry.select("attention", ...)',
    "use_attention_impl": "registry.use_impl(attention=..., "
                          "paged_decode=...)",
    "attention_impl_override": 'registry.override_for("attention")',
    "run_attention": 'registry.run("attention", ..., impl=name)',
    "select_paged_decode_impl": 'registry.select("paged_decode", ...)',
    "run_paged_decode": 'registry.run("paged_decode", ..., impl=name)',
    "DEFAULT_BLOCKS": "registry.DEFAULT_BLOCKS",
    "DEFAULT_CANDIDATES": "registry.DEFAULT_CANDIDATES",
    "TuneRecord": "registry.TuneRecord",
    "vmem_footprint": "registry.attention_vmem",
    "tune_key": "registry.attention_tune_key",
    "autotune_flash_blocks": 'registry.autotune("attention", session, ...)',
    "best_blocks": 'registry.best("attention", ...)',
    "record_blocks": 'registry.record("attention", key, (bq, bk))',
    "clear_table": "registry.clear_tune_table()",
    "DEFAULT_PAGES_PER_BLOCK": "registry.DEFAULT_PAGES_PER_BLOCK",
    "DEFAULT_PAGED_CANDIDATES": "registry.DEFAULT_PAGED_CANDIDATES",
    "PagedTuneRecord": "registry.TuneRecord",
    "paged_tune_key": "registry.paged_lookup_key",
    "paged_vmem_footprint": "registry.paged_vmem",
    "autotune_paged_decode": 'registry.autotune("paged_decode", '
                             'session, ...)',
    "best_paged_block": 'registry.best("paged_decode", ...)[1]',
}


def stub_getattr(module: str):
    """PEP-562 ``__getattr__`` factory for the ``dispatch.py`` /
    ``autotune.py`` re-export stubs.

    The old star-import stubs resolved attributes silently, so ``from
    repro.kernels.dispatch import ATTENTION_IMPLS`` (or any constant)
    never warned and the module-level spelling of every call site went
    unsurfaced.  Routing attribute access through here warns once per
    (deprecated module, symbol) — every legacy import line names itself
    exactly once."""
    def __getattr__(name: str):
        if name.startswith("__") or name not in __all__:
            raise AttributeError(
                f"module {module!r} has no attribute {name!r}")
        _deprecated(name,
                    _STUB_REPLACEMENTS.get(
                        name, f"repro.kernels.registry.{name}"),
                    module=module)
        return globals()[name]
    return __getattr__


# ---------------------------------------------------------------------------
# dispatch surface (the PR 3/4 attention + paged-decode ladders)
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def use_attention_impl(name: Optional[str]):
    """Force every attention dispatch traced inside the block to ``name``.

    Legacy spelling: the single name expands through
    ``registry.LEGACY_ATTN_MAP`` onto the attention AND paged_decode
    families (``"paged_decode"`` touches only the decode side).
    Thread-local; ``None`` is a no-op so callers can thread an optional
    config field straight through.
    """
    _deprecated("use_attention_impl",
                "registry.use_impl(attention=..., paged_decode=...)")
    if name is None:
        with registry.use_impl():
            yield
        return
    mapping = registry.LEGACY_ATTN_MAP.get(name)
    if mapping is None:
        raise ValueError(f"unknown attention impl {name!r}; "
                         f"choose from {OVERRIDE_IMPLS}")
    with registry.use_impl(**mapping):
        yield


def attention_impl_override() -> Optional[str]:
    """The active forced impl in LEGACY vocabulary: the attention-family
    override if one is set, ``"paged_decode"`` when only the decode side
    is pinned to the Pallas paged kernel, else None."""
    _deprecated("attention_impl_override", 'registry.override_for("attention")')
    attn = registry.override_for("attention")
    if attn is not None:
        return attn
    if registry.override_for("paged_decode") == "pallas_paged":
        return "paged_decode"
    return None


def select_attention_impl(*, sq: int, sk: int, dh: int, causal: bool = True,
                          backend: Optional[str] = None,
                          flash_min_seq: Optional[int] = None,
                          differentiable: bool = False) -> str:
    """Pick an implementation name from STATIC facts only (trace-time).

    ``flash_min_seq``: on jnp backends, q lengths above it use the online-
    softmax twin instead of materializing [.,Sq,Sk] (callers pass their
    ``chunk_threshold``).  ``differentiable=True`` pins the flash custom-VJP
    twin — the Pallas kernel is forward-only.  An override (env/context)
    beats every heuristic, including ``differentiable``.
    """
    _deprecated("select_attention_impl", 'registry.select("attention", ...)')
    return registry.select("attention", sq=sq, sk=sk, dh=dh, causal=causal,
                           backend=backend, flash_min_seq=flash_min_seq,
                           differentiable=differentiable)


def run_attention(name: str, q, k, v, *, q_offset=0, causal: bool = True,
                  kv_len=None, softmax_mode: str = "naive",
                  chunk_size: int = 512, chunk_threshold: int = 2048,
                  blocks: Optional[Tuple[int, int]] = None,
                  interpret: Optional[bool] = None):
    """Run impl ``name`` in model layout (q [B,Sq,H,Dh], k/v [B,Sk,KVH,Dh]).

    ``kv_len`` (scalar or [B], may be traced) masks right-padded/ragged
    keys; ``q_offset`` (scalar, may be traced) positions query 0 on the key
    axis.  ``softmax_mode``/``chunk_*`` parameterize the ``full`` impl;
    ``blocks``/``interpret`` the ``pallas_flash`` impl.
    """
    _deprecated("run_attention", 'registry.run("attention", ..., impl=name)')
    if name == "paged_decode":
        raise ValueError("paged_decode is a decode-attention impl; use "
                         "select_paged_decode_impl/run_paged_decode (it is "
                         "only a valid *override* name, pinning the decode "
                         "side while prefill keeps its heuristics)")
    if name not in ATTENTION_IMPLS:
        raise ValueError(f"unknown attention impl {name!r}; "
                         f"choose from {ATTENTION_IMPLS}")
    return registry.run("attention", q, k, v, impl=name, q_offset=q_offset,
                        causal=causal, kv_len=kv_len,
                        softmax_mode=softmax_mode, chunk_size=chunk_size,
                        chunk_threshold=chunk_threshold, blocks=blocks,
                        interpret=interpret)


def select_paged_decode_impl(*, backend: Optional[str] = None) -> str:
    """Pick the paged decode-attention implementation (trace-time, static).

    The SAME override ladder as prefill — the legacy names map onto the
    paged family (``paged_decode``/``pallas_flash`` force the Pallas
    kernel, ``jnp_flash``/``full`` force the gather-based reference) and
    ``registry.use_impl(paged_decode=...)`` / ``REPRO_IMPL`` pin it
    directly.  Unforced: TPU compiles the kernel, interpret-mode hosts
    take the reference — same policy as prefill.
    """
    _deprecated("select_paged_decode_impl",
                'registry.select("paged_decode", ...)')
    return registry.select("paged_decode", backend=backend)


def run_paged_decode(name: str, q, k_pages, v_pages, page_table, length,
                     k_new, v_new, *, pages_per_block: Optional[int] = None,
                     interpret: Optional[bool] = None):
    """Run paged decode impl ``name`` in model layout.

    q [B,1,H,Dh]; k/v_pages [P,ps,KVH,Dh] (one layer's pool slice);
    page_table [B,NP] int32; length [B] int32 (past tokens — the new
    token's K/V ride separately in ``k_new``/``v_new`` [B,1,KVH,Dh] and
    are folded into the softmax, NOT written; the caller scatters them
    into their page afterwards).  Returns [B,1,H,Dh].
    """
    _deprecated("run_paged_decode",
                'registry.run("paged_decode", ..., impl=name)')
    if name not in PAGED_DECODE_IMPLS:
        raise ValueError(f"unknown paged decode impl {name!r}; "
                         f"choose from {PAGED_DECODE_IMPLS}")
    return registry.run("paged_decode", q, k_pages, v_pages, page_table,
                        length, k_new, v_new, impl=name,
                        pages_per_block=pages_per_block,
                        interpret=interpret)


# ---------------------------------------------------------------------------
# autotune surface (the PR 3/4 sweep entry points + record types)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TuneRecord:
    """Outcome of one flash-blocks sweep (all candidates + the winner)."""

    key: str
    bq: int
    bk: int
    score_s: float                       # roofline seconds of the winner
    scores: Dict[Tuple[int, int], float]  # candidate -> score (inf = skipped)
    lowerings: int                       # real compiles this sweep (0 = warm)


@dataclasses.dataclass(frozen=True)
class PagedTuneRecord:
    """Outcome of one paged-decode sweep (all candidates + the winner)."""

    key: str
    page_size: int
    pages_per_block: int
    score_s: float
    scores: Dict[Tuple[int, int], float]  # (ps, ppb) -> score (inf = skipped)
    lowerings: int


def vmem_footprint(bq: int, bk: int, dh: int, itemsize: int = 4) -> int:
    """Bytes of VMEM the flash kernel needs for one (bq, bk) tile pair."""
    _deprecated("vmem_footprint", "registry.attention_vmem(...)")
    return registry.attention_vmem(bq, bk, dh, itemsize)


def paged_vmem_footprint(ps: int, ppb: int, g: int, dh: int,
                         itemsize: int = 4) -> int:
    """VMEM bytes for one paged-decode grid step."""
    _deprecated("paged_vmem_footprint", "registry.paged_vmem(...)")
    return registry.paged_vmem(ps, ppb, g, dh, itemsize)


def tune_key(*, b: int, h: int, kvh: int, sq: int, sk: int, dh: int,
             dtype, causal: bool, backend: Optional[str] = None) -> str:
    """The attention tune key (batch bucketed to powers of two)."""
    _deprecated("tune_key", "registry.attention_tune_key(...)")
    return registry.attention_tune_key(b=b, h=h, kvh=kvh, sq=sq, sk=sk,
                                       dh=dh, dtype=dtype, causal=causal,
                                       backend=backend)


def paged_tune_key(*, b: int, kvh: int, g: int, dh: int, page_size: int,
                   dtype, backend: Optional[str] = None) -> str:
    """The paged lookup key (page-table-width-agnostic, as ever)."""
    _deprecated("paged_tune_key", "registry.paged_lookup_key(...)")
    return registry.paged_lookup_key(b=b, kvh=kvh, g=g, dh=dh,
                                     page_size=page_size, dtype=dtype,
                                     backend=backend)


def autotune_flash_blocks(*, b: int, h: int, kvh: int, sq: int, sk: int,
                          dh: int, session, dtype=jnp.float32,
                          causal: bool = True,
                          candidates: Optional[Sequence[Tuple[int, int]]] = None,
                          chip: Optional[hwinfo.ChipSpec] = None,
                          backend: Optional[str] = None,
                          interpret: Optional[bool] = None,
                          vmem_fraction: float = 0.9) -> TuneRecord:
    """Sweep (bq, bk) candidates for one attention shape; record the winner.

    Delegates to ``registry.autotune("attention", ...)``: probes go
    through ``session.measure`` (lower+compile cold, disk lookup warm,
    never executed) and the whole sweep outcome persists in the artifact
    cache — a repeat in a FRESH process returns the stored record with
    zero sweeps and zero lowerings.
    """
    _deprecated("autotune_flash_blocks",
                'registry.autotune("attention", session, ...)')
    rec = registry.autotune("attention", session, candidates=candidates,
                            chip=chip, backend=backend, interpret=interpret,
                            vmem_fraction=vmem_fraction, b=b, h=h, kvh=kvh,
                            sq=sq, sk=sk, dh=dh, dtype=dtype, causal=causal)
    return TuneRecord(key=rec.key, bq=rec.choice[0], bk=rec.choice[1],
                      score_s=rec.score_s, scores=dict(rec.scores),
                      lowerings=rec.lowerings)


def best_blocks(*, b: int, h: int, kvh: int, sq: int, sk: int, dh: int,
                dtype, causal: bool,
                backend: Optional[str] = None) -> Tuple[int, int]:
    """The tuned tiling for this shape if a sweep recorded one (in this
    process or on disk), else an interpolated neighbor-bucket winner,
    else the MXU-shaped default.  The key buckets ``b`` to powers of
    two, so the scheduler's varying live mixes find the sweep's record."""
    _deprecated("best_blocks", 'registry.best("attention", ...)')
    return tuple(registry.best("attention", b=b, h=h, kvh=kvh, sq=sq, sk=sk,
                               dh=dh, dtype=dtype, causal=causal,
                               backend=backend))


def record_blocks(key: str, bq: int, bk: int) -> None:
    """Pin a tiling manually (e.g. replayed from a saved bench record)."""
    _deprecated("record_blocks", 'registry.record("attention", key, (bq, bk))')
    registry.record("attention", key, (bq, bk))


def clear_table() -> None:
    """Forget every in-process winner (disk-persisted records survive)."""
    _deprecated("clear_table", "registry.clear_tune_table()")
    registry.clear_tune_table()


def autotune_paged_decode(*, b: int, kvh: int, g: int, dh: int, ctx: int,
                          session, dtype=jnp.float32,
                          candidates: Optional[Sequence[Tuple[int, int]]] = None,
                          chip: Optional[hwinfo.ChipSpec] = None,
                          backend: Optional[str] = None,
                          interpret: Optional[bool] = None,
                          vmem_fraction: float = 0.9) -> PagedTuneRecord:
    """Sweep (page_size, pages_per_block) for a decode shape serving up to
    ``ctx`` tokens of context per row; record winners per page_size.

    Delegates to ``registry.autotune("paged_decode", ...)``; the winner
    per page_size lands in the table ``run_paged_decode`` consults (and
    on disk for the next process), and the overall winner's
    ``page_size`` is the pool-sizing recommendation for the launcher.
    """
    _deprecated("autotune_paged_decode",
                'registry.autotune("paged_decode", session, ...)')
    rec = registry.autotune("paged_decode", session, candidates=candidates,
                            chip=chip, backend=backend, interpret=interpret,
                            vmem_fraction=vmem_fraction, b=b, kvh=kvh, g=g,
                            dh=dh, ctx=ctx, dtype=dtype)
    ps_win, ppb_win = rec.choice
    win_key = registry.paged_lookup_key(b=b, kvh=kvh, g=g, dh=dh,
                                        page_size=ps_win, dtype=dtype,
                                        backend=backend)
    return PagedTuneRecord(key=win_key, page_size=ps_win,
                           pages_per_block=ppb_win, score_s=rec.score_s,
                           scores=dict(rec.scores), lowerings=rec.lowerings)


def best_paged_block(*, b: int, kvh: int, g: int, dh: int, page_size: int,
                     dtype, backend: Optional[str] = None) -> int:
    """The tuned pages_per_block for this shape/page_size if a sweep
    recorded one (in this process or on disk), else the default —
    width-agnostic, so every live-mix bucket the scheduler traces finds
    the same record."""
    _deprecated("best_paged_block", 'registry.best("paged_decode", ...)[1]')
    return registry.best("paged_decode", b=b, kvh=kvh, g=g, dh=dh,
                         page_size=page_size, dtype=dtype,
                         backend=backend)[1]
