"""jit'd wrappers adapting kernels to model layouts + kernel-fn factories.

The model zoo passes ``use_kernel_fn`` closures into its attention / linear-
scan call sites; these factories build them:

* :func:`make_flash_attention_fn` — BSHD <-> BHSD adapter around
  kernels/flash_attention.py (drop-in for the jnp chunked attention path).
* :func:`make_ssd_scan_fn` — [B,S,H,d] <-> [BH,S,d] adapter around
  kernels/ssd_scan.py, returning (y, (C,n)) exactly like
  models.linear_scan.chunked_linear_attention.

``interpret=True`` everywhere in this container (CPU validation); on real
TPU the same wrappers run compiled (interpret=False via REPRO_KERNEL_COMPILE).
"""

from __future__ import annotations

import os
from typing import Callable, Tuple

import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.jacobi7 import jacobi7_naive, jacobi7_wavefront
from repro.kernels.ssd_scan import ssd_scan_flat
from repro.kernels.stream_triad import stream_triad

__all__ = ["INTERPRET", "flash_attention", "ssd_scan",
           "make_flash_attention_fn", "make_ssd_scan_fn",
           "stream_triad", "jacobi7_naive", "jacobi7_wavefront"]

#: interpret-mode default: CPU container -> True; flip on real TPU.
#: (kept for back-compat; the flash path now resolves through
#: dispatch.default_interpret, which also detects the backend)
INTERPRET = os.environ.get("REPRO_KERNEL_COMPILE", "0") != "1"


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, q_offset=0, kv_valid=None,
                    bq: int = 128, bk: int = 256,
                    interpret: bool | None = None) -> jnp.ndarray:
    """BSHD layout: q [B,Sq,H,Dh]; k,v [B,Sk,KVH,Dh] -> [B,Sq,H,Dh].

    ``q_offset``/``kv_valid`` as in :func:`flash_attention_bhsd` (cached
    prefill offsets + ragged KV); ``interpret=None`` -> backend detection.
    """
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, q_offset=q_offset,
                               kv_valid=kv_valid, bq=bq, bk=bk,
                               interpret=interpret)
    return out.transpose(0, 2, 1, 3)


def ssd_scan(q, k, v, log_f, log_i, *, chunk: int = 128,
             normalize: bool = False, interpret: bool | None = None
             ) -> Tuple[jnp.ndarray, Tuple]:
    """Model layout: q,k [B,S,H,dk]; v [B,S,H,dv]; gates [B,S,H].

    Returns (y [B,S,H,dv], (C [B,H,dk,dv], n [B,H,dk])) — the
    chunked_linear_attention contract.
    """
    itp = INTERPRET if interpret is None else interpret
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    flat = lambda a: a.transpose(0, 2, 1, *range(3, a.ndim)).reshape(
        b * h, s, *a.shape[3:])
    y, (c_st, n_st) = ssd_scan_flat(
        flat(q), flat(k), flat(v), flat(log_f), flat(log_i),
        chunk=chunk, normalize=normalize, interpret=itp)
    y = y.reshape(b, h, s, dv).transpose(0, 2, 1, 3)
    return y, (c_st.reshape(b, h, dk, dv), n_st.reshape(b, h, dk))


def make_flash_attention_fn(bq: int = 128, bk: int = 256,
                            causal: bool = True) -> Callable:
    """use_kernel_fn for repro.models.attention.attention()."""
    def fn(q, k, v):
        return flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    return fn


def make_ssd_scan_fn(chunk: int = 128, normalize: bool = False) -> Callable:
    """use_kernel_fn for repro.models.linear_scan.chunked_linear_attention()."""
    def fn(q, k, v, log_f, log_i):
        return ssd_scan(q, k, v, log_f, log_i, chunk=chunk,
                        normalize=normalize)
    return fn
