"""STREAM triad Pallas kernel (paper case study 1, §III).

a = b + s*c, tiled into VMEM-resident blocks.  The grid walks [M, 128]-
shaped tiles (lane-aligned minor dim) and the Pallas pipeline double-buffers
HBM->VMEM streams (features.prefetch_to_vmem toggles the analogue of the
paper's hardware prefetchers by collapsing the grid to one giant block —
no pipelining, one shot).

Traffic model (the bandwidth-map tool reads this): 3 streams x N x 4 B per
call — read b, read c, write a; no write-allocate on TPU (stores do not
read the destination line), so the kernel is the paper's "NT store" case
by construction.

Registered as the ``stream_triad`` family in kernels/registry.py
(``pallas_triad`` — this kernel — vs the ``xla_triad`` baseline);
``block_rows`` is its tune space.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl

__all__ = ["stream_triad_kernel", "stream_triad"]

LANES = 128


def stream_triad_kernel(b_ref, c_ref, a_ref, *, s: float):
    a_ref[...] = b_ref[...] + s * c_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("s", "block_rows", "interpret",
                                    "pipelined"))
def stream_triad(b: jnp.ndarray, c: jnp.ndarray, *, s: float = 2.5,
                 block_rows: int = 256, interpret: bool = True,
                 pipelined: bool = True) -> jnp.ndarray:
    """b, c: flat [N] arrays with N % 128 == 0.  Returns a = b + s*c."""
    assert b.shape == c.shape and b.ndim == 1, (b.shape, c.shape)
    n = b.shape[0]
    assert n % LANES == 0, f"N={n} must be lane-aligned ({LANES})"
    rows = n // LANES
    b2 = b.reshape(rows, LANES)
    c2 = c.reshape(rows, LANES)
    br = min(block_rows, rows) if pipelined else rows
    # pad rows to a multiple of the block
    pad = (-rows) % br
    if pad:
        b2 = jnp.pad(b2, ((0, pad), (0, 0)))
        c2 = jnp.pad(c2, ((0, pad), (0, 0)))
    grid = (b2.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(stream_triad_kernel, s=s),
        grid=grid,
        in_specs=[pl.BlockSpec((br, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((br, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(b2.shape, b.dtype),
        interpret=interpret,
    )(b2, c2)
    return out[:rows].reshape(n)


def triad_bytes(n: int, dtype_bytes: int = 4) -> int:
    """Modeled HBM traffic per call (3 streams, no write-allocate)."""
    return 3 * n * dtype_bytes
