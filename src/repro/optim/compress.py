"""int8 error-feedback gradient compression (distributed-optimization trick).

Before the data-parallel reduce, each gradient tensor is quantized to int8
with a per-tensor scale; the quantization residual is kept locally and added
back into the next step's gradient (error feedback, Karimireddy et al. 2019)
so the scheme is unbiased over time.

On a real pod the int8 tensors are what crosses the wire (4x less DP reduce
traffic — the roofline ICI term shrinks accordingly; recorded as a feature
experiment in EXPERIMENTS.md).  Under XLA SPMD autodiff the reduce itself is
compiler-inserted, so this module implements the *numerics* (quantize ->
dequantize with EF residual); the wire format is modeled, not re-plumbed —
see DESIGN.md §9.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_compress_state", "compress_decompress", "quantize_int8",
           "dequantize_int8"]

CompressState = Any  # pytree of f32 residuals, like params


def init_compress_state(params: Any) -> CompressState:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads: Any, ef: CompressState
                        ) -> Tuple[Any, CompressState]:
    """Apply EF-int8 to every gradient leaf.  Returns (grads', ef')."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        return deq, corrected - deq

    flat = jax.tree.map(one, grads, ef)
    new_grads = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_ef
