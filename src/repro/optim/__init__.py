from repro.optim.adamw import (AdamWConfig, OptState, apply_updates,  # noqa
                               global_norm, init_opt_state)
from repro.optim.schedule import ScheduleConfig, lr_at  # noqa: F401
