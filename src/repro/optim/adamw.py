"""AdamW with global-norm clipping and optional int8 error-feedback grad
compression (the distributed-optimization trick; see compress.py).

Params live in f32 (models cast to bf16 at the use site), so no separate
master copy is needed; optimizer state = (m, v) in f32, sharded like the
params (same logical axes -> same PartitionSpecs).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.compress import (CompressState, compress_decompress,
                                  init_compress_state)

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "apply_updates",
           "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_compression: str = "none"    # none | int8_ef
    moment_dtype: str = "float32"     # float32 | bfloat16 (HBM knob for the
                                      # 123B/235B cells: halves m+v footprint)


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray
    ef: Optional[Any] = None          # error-feedback residuals (int8_ef)


def init_opt_state(params: Any, cfg: AdamWConfig) -> OptState:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, mdt), params)
    ef = (init_compress_state(params) if cfg.grad_compression == "int8_ef"
          else None)
    return OptState(m=zeros(), v=zeros(), step=jnp.zeros((), jnp.int32),
                    ef=ef)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params: Any, grads: Any, opt: OptState, lr: jnp.ndarray,
                  cfg: AdamWConfig) -> Tuple[Any, OptState, Dict[str, Any]]:
    """One AdamW step.  Returns (params', opt', metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    ef = opt.ef
    if cfg.grad_compression == "int8_ef":
        grads, ef = compress_decompress(grads, ef)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)

    step = opt.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moment_dtype)
    new_m = jax.tree.map(
        lambda m, g: (cfg.b1 * m.astype(jnp.float32)
                      + (1 - cfg.b1) * g).astype(mdt), opt.m, grads)
    new_v = jax.tree.map(
        lambda v, g: (cfg.b2 * v.astype(jnp.float32)
                      + (1 - cfg.b2) * g * g).astype(mdt), opt.v, grads)

    def upd(p, m, v):
        mhat = m.astype(jnp.float32) / b1c
        vhat = v.astype(jnp.float32) / b2c
        u = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:   # no decay on norms/bias
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(new_m, new_v, step, ef), metrics
