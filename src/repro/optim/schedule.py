"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["ScheduleConfig", "lr_at"]


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_ratio: float = 0.1
    kind: str = "cosine"          # cosine | linear | constant


def lr_at(step: jnp.ndarray, cfg: ScheduleConfig) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = cfg.peak_lr * jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    if cfg.kind == "cosine":
        decay = cfg.min_ratio + (1 - cfg.min_ratio) * 0.5 * \
            (1 + jnp.cos(jnp.pi * frac))
    elif cfg.kind == "linear":
        decay = cfg.min_ratio + (1 - cfg.min_ratio) * (1 - frac)
    else:
        decay = jnp.ones(())
    return jnp.where(s < cfg.warmup_steps, warm, cfg.peak_lr * decay)
