"""Speculative decoding: draft/target pairing, accept policies, round math.

The subsystem couples a small *draft* model with the serving *target*
model inside one jitted program.  A **spec round** (built by
``Engine._spec_round``, math here) is:

1. sample the pending token ``y`` from the carried logits,
2. draft ``K = num_draft_tokens`` candidates ``d_1..d_K`` with the
   draft model (K+1 decode steps so the draft cache also covers
   ``d_K``'s position and rewinds uniformly),
3. verify the whole suffix ``[y, d_1..d_K]`` with the target model in
   ONE multi-token segment through the chunked-prefill path
   (``lm.prefill(..., prefix_len=row_lengths, all_logits=True)``) —
   K+1 next-token distributions ``o_0..o_K`` for one forward pass,
4. accept the longest prefix ``d_1..d_a`` the policy admits, rewind
   both models' per-row cache lengths to ``len + a + 1`` (rejected
   draft tokens simply fall out of the attended window; their pages are
   overwritten by the next round's writes),
5. carry logits that make the NEXT round's ``y`` the correct
   "extra" token (bonus / residual / rollback sample).

Accept policies (``SpecConfig.accept_policy``):

* ``greedy`` (temperature 0): ``d_i`` is accepted iff it equals
  ``argmax(o_{i-1})``; the carried logits are ``o_a`` verbatim, so every
  emitted token is the argmax of a target-model logit row at the exact
  context target-only decode would have used — greedy speculative tokens
  are **bit-identical** to target-only decode.
* ``rejection`` (temperature > 0): the standard speculative-sampling
  correction.  ``d_i ~ q_i`` is accepted with probability
  ``min(1, p_i(d_i) / q_i(d_i))``; on the first rejection the carried
  distribution is the residual ``norm(max(p_a - q_{a+1}, 0))``, after K
  acceptances it is the bonus ``p_K``.  The carried logits are
  ``T * log(dist)`` so the engine's ordinary
  ``categorical(logits / T)`` sample IS the residual/bonus draw — the
  emitted token stream is distributed exactly as target-only sampling
  (testable against the target distribution on a seeded grid).
* ``auto``: resolves to ``greedy`` when ``temperature <= 0`` else
  ``rejection``.

Mixed batches: rows with ``spec_mask=False`` force ``a = 0`` and carry
the plain target distribution ``p_0`` (NOT the residual — that would
skew a non-spec row's sampling), so a non-spec row emits exactly one
token per round while spec rows emit up to K+1.
"""

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["SpecConfig", "accept_speculative", "ACCEPT_POLICIES"]

ACCEPT_POLICIES = ("auto", "greedy", "rejection")


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Draft/target pairing for speculative decoding.

    ``draft_config`` is the draft model's LMConfig (the config zoo spans
    qwen2-0.5b .. mistral-123b — exactly a draft/target pair);
    ``num_draft_tokens`` is K, the draft lookahead per round.
    """
    draft_config: Any                  # models.lm.LMConfig of the draft
    num_draft_tokens: int = 4
    accept_policy: str = "auto"        # auto | greedy | rejection

    def resolve_policy(self, temperature: float) -> str:
        if self.accept_policy != "auto":
            return self.accept_policy
        return "greedy" if temperature <= 0.0 else "rejection"

    def signature(self) -> Tuple:
        """Snapshot-compat identity: restoring under a different pairing
        could not reproduce the token stream."""
        return (getattr(self.draft_config, "name", "?"),
                int(self.num_draft_tokens), self.accept_policy)

    def validate(self, target_cfg, serve_cfg=None) -> None:
        """Eager construction-time checks (Engine init and launch/cli.py
        both call this, so a bad pairing fails before any tracing)."""
        from repro.serve.engine import MASKED_FAMILIES
        k = int(self.num_draft_tokens)
        if k < 1:
            raise ValueError(f"num_draft_tokens must be >= 1, got {k}")
        if self.accept_policy not in ACCEPT_POLICIES:
            raise ValueError(
                f"unknown accept_policy {self.accept_policy!r}; choose "
                f"from {ACCEPT_POLICIES}")
        dc = self.draft_config
        if dc.vocab != target_cfg.vocab:
            raise ValueError(
                f"draft/target vocab mismatch: draft {dc.name!r} has "
                f"vocab={dc.vocab}, target {target_cfg.name!r} has "
                f"vocab={target_cfg.vocab} — verified tokens index one "
                f"shared vocabulary")
        for role, cfg in (("draft", dc), ("target", target_cfg)):
            if cfg.family not in MASKED_FAMILIES:
                raise ValueError(
                    f"speculative decoding needs an attention-cache "
                    f"decoder family ({MASKED_FAMILIES}); {role} config "
                    f"{cfg.name!r} is {cfg.family!r}"
                    + (" — encoder-decoder configs are unsupported"
                       if cfg.family == "encdec" else ""))
        if serve_cfg is not None:
            if serve_cfg.page_size <= 0:
                raise ValueError(
                    "speculative decoding needs a paged engine "
                    "(ServeConfig.page_size > 0): verify runs through the "
                    "paged suffix-prefill path and rollback rewinds "
                    "per-row page lengths")
            policy = self.resolve_policy(serve_cfg.temperature)
            if policy == "greedy" and serve_cfg.temperature > 0.0:
                raise ValueError(
                    "accept_policy='greedy' needs temperature 0 (exact "
                    "prefix match against the target argmax)")
            if policy == "rejection" and serve_cfg.temperature <= 0.0:
                raise ValueError(
                    "accept_policy='rejection' needs temperature > 0 "
                    "(use 'greedy' or 'auto' for deterministic decode)")
            if policy == "rejection" and (
                    getattr(serve_cfg, "top_k", 0)
                    or getattr(serve_cfg, "top_p", 1.0) < 1.0):
                raise ValueError(
                    "speculative rejection sampling supports "
                    "temperature-only sampling: the carried residual "
                    "distribution is already corrected, so a top-k/top-p "
                    "refilter of it would skew the accepted stream")


def accept_speculative(draft_tokens: jnp.ndarray,
                       draft_logits: jnp.ndarray,
                       target_logits: jnp.ndarray,
                       key=None, *, policy: str,
                       temperature: float = 0.0,
                       spec_mask: Optional[jnp.ndarray] = None
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Longest-accepted-prefix + carried logits for one spec round.

    Args:
      draft_tokens: [B, K] int32 — ``d_1..d_K`` sampled from the draft.
      draft_logits: [B, K, V] — draft logits ``q_1..q_K`` each ``d_i``
        was sampled from (pre-temperature, as produced by the model).
      target_logits: [B, K+1, V] — verify logits ``o_0..o_K``; ``o_i``
        conditions on ``y, d_1..d_i``.
      key: PRNG key for the rejection draws (unused for greedy).
      policy: "greedy" | "rejection" (resolved — not "auto").
      temperature: sampling temperature (rejection only).
      spec_mask: [B] bool; False rows force ``a=0`` and carry the plain
        target distribution (mixed spec/non-spec batches).

    Returns ``(accepted [B] int32 in [0..K], carry_logits [B, V])`` where
    sampling the engine's usual way from ``carry_logits`` (argmax for
    greedy, ``categorical(carry / T)`` for rejection) produces the
    round's final token with the exact corrected distribution.
    """
    b, k = draft_tokens.shape
    if spec_mask is None:
        spec_mask = jnp.ones((b,), bool)
    if policy == "greedy":
        tgt = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)
        flags = (draft_tokens == tgt[:, :k]) & spec_mask[:, None]
        acc = jnp.cumprod(flags.astype(jnp.int32), axis=1).sum(axis=1)
        carry = jnp.take_along_axis(
            target_logits, acc[:, None, None], axis=1)[:, 0]
        return acc, carry
    if policy != "rejection":
        raise ValueError(f"unresolved accept policy {policy!r}")
    from repro.kernels.sampling import filtered_logits
    t = float(temperature)
    q = jax.nn.softmax(filtered_logits(draft_logits, temperature=t),
                       axis=-1)                               # [B,K,V]
    p = jax.nn.softmax(filtered_logits(target_logits, temperature=t),
                       axis=-1)                               # [B,K+1,V]
    u = jax.random.uniform(key, (b, k))
    q_tok = jnp.take_along_axis(q, draft_tokens[..., None],
                                axis=-1)[..., 0]              # [B,K]
    p_tok = jnp.take_along_axis(p[:, :k], draft_tokens[..., None],
                                axis=-1)[..., 0]
    # accept d_i with prob min(1, p/q): u*q < p avoids the div (q>0 by
    # construction — the draft sampled d_i from q)
    ok = (u * q_tok < p_tok) & spec_mask[:, None]
    acc = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
    p_a = jnp.take_along_axis(p, acc[:, None, None], axis=1)[:, 0]
    # residual needs q at the REJECTED position; pad q with zeros at K so
    # full acceptance (a=K) degenerates to the bonus draw from p_K
    q_pad = jnp.concatenate([q, jnp.zeros_like(q[:, :1])], axis=1)
    q_a = jnp.take_along_axis(q_pad, acc[:, None, None], axis=1)[:, 0]
    # non-spec rows carry the PLAIN target distribution p_0 (their a is
    # forced to 0 above; subtracting q_1 would skew an ordinary sample)
    q_a = jnp.where(spec_mask[:, None], q_a, 0.0)
    dist = jnp.maximum(p_a - q_a, 0.0)
    norm = dist.sum(axis=-1, keepdims=True)
    # degenerate all-zero residual (p == q to fp rounding): fall back to
    # the target distribution itself — identical in the limit
    dist = jnp.where(norm > 0.0, dist, p_a)
    # carried as T*log(dist): the engine's categorical(carry / T) then
    # samples exactly from dist
    return acc, t * jnp.log(dist)
