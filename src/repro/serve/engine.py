"""Serving engine: batched prefill + decode with slot-based scheduling.

Two layers:

* :class:`Engine` — the jitted compute: batched ``prefill`` (padded prompts,
  right-aligned masks) and ``decode_step`` with temperature/greedy sampling.
  Works for every LM family (KV caches, recurrent states, enc-dec memories
  all live behind ``lm.init_decode_state``).
* :class:`BatchScheduler` — continuous-batching-lite: fixed decode slots;
  finished sequences release their slot and queued requests take it over
  (their prompt runs through a single-slot prefill into the shared state).

Sampling is deterministic given (seed, request id) — serving is replayable,
the same philosophy as the data pipeline.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM

__all__ = ["ServeConfig", "Engine", "BatchScheduler", "Request"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 1024
    batch_slots: int = 4
    temperature: float = 0.0        # 0 -> greedy
    eos_token: int = -1             # -1 -> never stop early
    seed: int = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class Engine:
    def __init__(self, lm: LM, params: Any, cfg: ServeConfig):
        self.lm = lm
        self.params = params
        self.cfg = cfg
        self._prefill = jax.jit(lm.prefill)
        self._decode = jax.jit(lm.decode_step)

    # -------------------------------------------------------------- helpers
    def _sample(self, logits: jnp.ndarray, rng) -> jnp.ndarray:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(rng, logits / self.cfg.temperature,
                                      axis=-1)

    def _pad_prompts(self, prompts: Sequence[Sequence[int]]
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Left-pad is avoided: prompts are right-padded and the model's
        causal mask makes pad positions inert; the last REAL token's logits
        are selected per row."""
        maxlen = max(len(p) for p in prompts)
        toks = np.zeros((len(prompts), maxlen), np.int32)
        lens = np.array([len(p) for p in prompts], np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        return toks, lens

    # ----------------------------------------------------------------- API
    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int = 32,
                 extra_batch: Optional[Dict[str, np.ndarray]] = None
                 ) -> List[List[int]]:
        """Static-batch generation (the examples/ and tests path)."""
        cfg = self.cfg
        toks, lens = self._pad_prompts(prompts)
        b = toks.shape[0]
        state = self.lm.init_decode_state(b, cfg.max_seq)
        batch: Dict[str, jnp.ndarray] = {"tokens": jnp.asarray(toks)}
        if extra_batch:
            batch.update({k: jnp.asarray(v) for k, v in extra_batch.items()})
        logits, state = self._prefill(self.params, batch, state)
        # NOTE: prompts are padded to a common length and pad tokens (id 0)
        # are ordinary context — a documented serving simplification; tests
        # use equal-length waves.  Per-row attention masks / paged KV are
        # listed as future work in DESIGN.md §9.
        rng = jax.random.PRNGKey(cfg.seed)
        out = [list() for _ in range(b)]
        done = np.zeros(b, bool)
        for t in range(max_new_tokens):
            rng, sub = jax.random.split(rng)
            nxt = self._sample(logits, sub)
            nxt_np = np.asarray(nxt)
            for i in range(b):
                if not done[i]:
                    out[i].append(int(nxt_np[i]))
                    if cfg.eos_token >= 0 and nxt_np[i] == cfg.eos_token:
                        done[i] = True
            if done.all():
                break
            logits, state = self._decode(self.params, nxt[:, None], state)
        return out


class BatchScheduler:
    """Continuous-batching-lite over an Engine's decode loop.

    Serves a queue of Requests with ``batch_slots`` concurrent sequences.
    A finished request frees its slot; the next queued request claims it
    (prefilling via single-row decode replay into the shared state).  The
    decode loop itself always runs the full batch — the TPU-friendly shape.
    """

    def __init__(self, engine: Engine):
        self.engine = engine
        self.queue: List[Request] = []
        self.completed: Dict[int, Request] = {}

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self) -> Dict[int, Request]:
        eng, cfg = self.engine, self.engine.cfg
        while self.queue:
            wave = [self.queue.pop(0)
                    for _ in range(min(cfg.batch_slots, len(self.queue)))]
            outs = eng.generate([r.prompt for r in wave],
                                max_new_tokens=max(r.max_new_tokens
                                                   for r in wave))
            for r, o in zip(wave, outs):
                r.generated = o[:r.max_new_tokens]
                self.completed[r.rid] = r
        return self.completed
