"""Serving engine: on-device fused decode + true continuous batching.

The hot path runs at device speed.  Two layers:

* :class:`Engine` — the jitted compute.  ``generate()`` fuses
  prefill -> [sample -> append -> eos-mask -> decode_step]* into a single
  jitted program (``lax.while_loop`` with on-device greedy/categorical
  sampling and per-row done masking), so one call is ONE dispatch and ONE
  device->host sync regardless of how many tokens it decodes — the old
  implementation round-tripped device->host once per token.  Ragged prompts
  are first-class for attention-cache families: per-row prompt-length masks
  keep pad keys out of every softmax and each row's cache advances at its
  own position (``models/lm.py prefill(lengths=...)``).
* :class:`BatchScheduler` — true continuous batching.  A slot table over
  ONE shared decode state: decode runs in jitted multi-token *segments*
  (``admission_chunk`` steps, decode state donated segment-to-segment so
  buffers are reused, not churned); after each segment a single host sync
  fetches the segment's tokens, finished rows release their slots
  immediately, and queued requests prefill into the freed slots mid-flight
  at their EXACT prompt length (single-row prefill, no padding — which is
  also what makes recurrent-state families batch raggedly here).

Prefill attention routes through the kernel dispatch layer
(:mod:`repro.kernels.dispatch`): on TPU the Pallas flash kernel is the
prefill path; ``ServeConfig.attn_impl`` pins a named implementation for
every program an engine traces (tests force ``pallas_flash`` on CPU to
prove token-identical output through the kernel).

Every device->host transfer goes through :meth:`Engine._fetch`, so
``engine.host_syncs`` is an auditable counter — tests assert the O(1)
bound and ``benchmarks/bench_serve.py`` reports it next to tokens/s.
Instrumentation is LIKWID-style (``Engine.instrument``): event counts for
the ``serve.decode`` / ``serve.prefill`` regions come from the compiled
artifact (wrapper mode, zero overhead), wall-clock accumulates into the
same regions via ``PerfCtr.region_timer``.

``generate()`` is fully deterministic given (seed, prompts).  In the
scheduler, greedy decoding (temperature 0, the default) is replayable
per-request; with temperature > 0 one PRNG stream is shared across slots,
so a request's samples depend on what it was co-scheduled with — the
continuous-batching trade, stated here rather than hidden.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM

__all__ = ["ServeConfig", "Engine", "BatchScheduler", "Request",
           "MASKED_FAMILIES"]

# families whose decode state is an attention cache: pad keys can be masked
# per row, so ragged prompts batch exactly.  Recurrent-state families
# (xlstm, hybrid) cannot un-run a pad token through a running state; they
# keep pads-as-context semantics in the static batched path and batch
# raggedly through the scheduler's exact-length slot prefill instead.
MASKED_FAMILIES = ("dense", "moe", "vlm")

PREFILL_REGION = "serve.prefill"
DECODE_REGION = "serve.decode"


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 1024
    batch_slots: int = 4
    temperature: float = 0.0        # 0 -> greedy
    eos_token: int = -1             # -1 -> never stop early
    seed: int = 0
    admission_chunk: int = 8        # decode steps between admission points
    # attention impl forced for every program this engine traces (None ->
    # repro.kernels.dispatch picks by backend/shape/$REPRO_ATTN_IMPL);
    # fixed per-engine because jitted programs are traced once and cached
    attn_impl: Optional[str] = None


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    submit_time: float = 0.0        # set by BatchScheduler.submit
    first_token_time: float = 0.0   # set when the first token reaches host
    finished: bool = False          # set by the scheduler (eos or budget)

    @property
    def done(self) -> bool:
        return self.finished or len(self.generated) >= self.max_new_tokens

    @property
    def ttft(self) -> Optional[float]:
        """Time-to-first-token (segment-granular), None until measured."""
        if self.first_token_time and self.submit_time:
            return self.first_token_time - self.submit_time
        return None


class Engine:
    def __init__(self, lm: LM, params: Any, cfg: ServeConfig,
                 perfctr=None):
        self.lm = lm
        self.params = params
        self.cfg = cfg
        self.perfctr = perfctr          # optional repro.core.perfctr.PerfCtr
        self.host_syncs = 0             # device->host transfers (audited)
        self.fused_calls = 0            # fused-loop dispatches
        self._prefill = jax.jit(lm.prefill)
        self._decode = jax.jit(lm.decode_step)
        # fused generate programs, keyed by static max_new_tokens
        self._fused: Dict[int, Callable] = {}
        # continuous-batching decode segments, keyed by static step count
        self._segments: Dict[int, Callable] = {}
        # slot prefill: init+prefill a single row in one jitted program
        self._slot_prefill = jax.jit(self._slot_prefill_impl)
        # slot merge: scatter a single-row state into the shared state;
        # the big buffers are donated — admission rewrites one row in place
        self._merge = jax.jit(self._merge_impl, donate_argnums=(0, 1))

    # -------------------------------------------------------------- helpers
    def _fetch(self, tree):
        """THE device->host sync point: every transfer is counted here."""
        self.host_syncs += 1
        return jax.device_get(tree)

    def _region_timer(self, region: str):
        return (self.perfctr.region_timer(region) if self.perfctr is not None
                else contextlib.nullcontext())

    def _impl_ctx(self):
        """Kernel-dispatch override while tracing/running engine programs.

        Prefill attention routes through repro.kernels.dispatch; pinning
        ``cfg.attn_impl`` here means every program this engine traces
        (fused generate, slot prefill, reference loop, instrument probes)
        resolves to the same implementation.
        """
        from repro.kernels import dispatch
        return dispatch.use_attention_impl(self.cfg.attn_impl)

    def _sample(self, logits: jnp.ndarray, rng) -> jnp.ndarray:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(rng, logits / self.cfg.temperature,
                                      axis=-1)

    def _pad_prompts(self, prompts: Sequence[Sequence[int]]
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Right-pad to the longest prompt; per-row true lengths ride along
        (attention families mask pad keys out via batch["lengths"])."""
        maxlen = max(len(p) for p in prompts)
        toks = np.zeros((len(prompts), maxlen), np.int32)
        lens = np.array([len(p) for p in prompts], np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        return toks, lens

    # ------------------------------------------------- fused generate (jit)
    def _make_fused(self, max_new: int) -> Callable:
        """Build the single-dispatch generate program for a fixed budget.

        prefill + the whole decode loop live in ONE jitted computation:
        the loop body samples on device, records the token into a [B,T]
        buffer, folds eos into a per-row done mask, and early-exits the
        while_loop as soon as every row is done — zero host round-trips.
        """
        cfg = self.cfg
        masked = self.lm.cfg.family in MASKED_FAMILIES

        def fused(params, toks, lens, rng, extra):
            b = toks.shape[0]
            # size the cache to THIS call's worst case, not cfg.max_seq:
            # every decode step streams the whole cache buffer, so capacity
            # the call can't reach is pure wasted traffic (rounded up so
            # nearby shapes share layouts)
            need = toks.shape[1] + max_new
            seq_cap = min(cfg.max_seq, -(-need // 32) * 32)
            state = self.lm.init_decode_state(b, seq_cap)
            batch = dict(extra, tokens=toks)
            if masked:
                batch["lengths"] = lens
            logits, state = self.lm.prefill(params, batch, state)

            def cond(c):
                t, _rng, _logits, _state, _out, done, _n = c
                return (t < max_new) & jnp.logical_not(done.all())

            def body(c):
                t, rng, logits, state, out, done, n = c
                rng, sub = jax.random.split(rng)
                nxt = self._sample(logits, sub).astype(jnp.int32)
                emit = jnp.logical_not(done)
                out = jax.lax.dynamic_update_slice(
                    out, jnp.where(emit, nxt, 0)[:, None], (0, t))
                n = n + emit.astype(jnp.int32)
                if cfg.eos_token >= 0:
                    done = done | (emit & (nxt == cfg.eos_token))
                logits, state = self.lm.decode_step(params, nxt[:, None],
                                                    state)
                return (t + 1, rng, logits, state, out, done, n)

            carry = (jnp.zeros((), jnp.int32), rng, logits, state,
                     jnp.zeros((b, max_new), jnp.int32),
                     jnp.zeros((b,), bool), jnp.zeros((b,), jnp.int32))
            carry = jax.lax.while_loop(cond, body, carry)
            return carry[4], carry[6]           # tokens [B,T], counts [B]

        return jax.jit(fused)

    # ----------------------------------------------------------------- API
    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int = 32,
                 extra_batch: Optional[Dict[str, np.ndarray]] = None
                 ) -> List[List[int]]:
        """Static-batch generation: one dispatch, one host sync."""
        cfg = self.cfg
        toks, lens = self._pad_prompts(prompts)
        if toks.shape[1] + max_new_tokens > cfg.max_seq:
            raise ValueError(
                f"prompt ({toks.shape[1]}) + max_new ({max_new_tokens}) "
                f"exceeds max_seq ({cfg.max_seq})")
        extra = ({k: jnp.asarray(v) for k, v in extra_batch.items()}
                 if extra_batch else {})
        fused = self._fused.get(max_new_tokens)
        if fused is None:
            fused = self._fused[max_new_tokens] = \
                self._make_fused(max_new_tokens)
        self.fused_calls += 1
        with self._region_timer(DECODE_REGION), self._impl_ctx():
            out, n = fused(self.params, jnp.asarray(toks), jnp.asarray(lens),
                           jax.random.PRNGKey(cfg.seed), extra)
            out_np, n_np = self._fetch((out, n))    # the ONE sync
        return [out_np[i, :n_np[i]].tolist() for i in range(len(prompts))]

    def generate_reference(self, prompts: Sequence[Sequence[int]],
                           max_new_tokens: int = 32,
                           extra_batch: Optional[Dict[str, np.ndarray]] = None
                           ) -> List[List[int]]:
        """The pre-fusion wave-mode loop: one dispatch AND one host sync
        per generated token, pads as ordinary context.

        Kept verbatim as (a) the measured baseline for
        ``benchmarks/bench_serve.py`` and (b) the semantic oracle the fused
        loop's tests compare against on equal-length prompts.
        """
        cfg = self.cfg
        toks, lens = self._pad_prompts(prompts)
        b = toks.shape[0]
        state = self.lm.init_decode_state(b, cfg.max_seq)
        batch: Dict[str, jnp.ndarray] = {"tokens": jnp.asarray(toks)}
        if extra_batch:
            batch.update({k: jnp.asarray(v) for k, v in extra_batch.items()})
        with self._impl_ctx():
            logits, state = self._prefill(self.params, batch, state)
        rng = jax.random.PRNGKey(cfg.seed)
        out = [list() for _ in range(b)]
        done = np.zeros(b, bool)
        for t in range(max_new_tokens):
            rng, sub = jax.random.split(rng)
            nxt = self._sample(logits, sub)
            nxt_np = self._fetch(nxt)            # per-token sync (the point)
            for i in range(b):
                if not done[i]:
                    out[i].append(int(nxt_np[i]))
                    if cfg.eos_token >= 0 and nxt_np[i] == cfg.eos_token:
                        done[i] = True
            if done.all():
                break
            logits, state = self._decode(self.params, nxt[:, None], state)
        return out

    # ------------------------------------- continuous-batching primitives
    def _slot_prefill_impl(self, params, toks):
        """Init + prefill ONE row at its exact prompt length (no padding)."""
        state = self.lm.init_decode_state(1, self.cfg.max_seq)
        return self.lm.prefill(params, {"tokens": toks}, state)

    @staticmethod
    def _merge_impl(state, logits_buf, row_state, row_logits, slot):
        """Scatter a single-row (state, logits) into slot `slot`.

        Every decode-state leaf is [layers, B, ...]; the row twin is
        [layers, 1, ...] — one dynamic_update_slice along the batch axis
        per leaf, with the big buffers donated (in-place admission).
        """
        merged = jax.tree.map(
            lambda big, row: jax.lax.dynamic_update_slice_in_dim(
                big, row.astype(big.dtype), slot, axis=1),
            state, row_state)
        logits_buf = jax.lax.dynamic_update_slice_in_dim(
            logits_buf, row_logits.astype(logits_buf.dtype), slot, axis=0)
        return merged, logits_buf

    def prefill_slot(self, state, logits_buf, prompt: Sequence[int],
                     slot: int):
        """Admission point: prefill `prompt` into slot `slot` mid-flight."""
        toks = jnp.asarray([list(prompt)], jnp.int32)
        with self._region_timer(PREFILL_REGION), self._impl_ctx():
            row_logits, row_state = self._slot_prefill(self.params, toks)
        return self._merge(state, logits_buf, row_state, row_logits,
                           jnp.asarray(slot, jnp.int32))

    def decode_segment(self, steps: int) -> Callable:
        """The jitted `steps`-token decode over all slots.

        ``lax.scan`` over the fused sample->decode body; decode state and
        the logits buffer are DONATED, so segment-to-segment the cache
        buffers alias instead of reallocating.  Returns
        (tokens [B,steps], logits, state, rng).
        """
        fn = self._segments.get(steps)
        if fn is None:
            def seg(params, state, logits, rng):
                def body(carry, _):
                    logits, state, rng = carry
                    rng, sub = jax.random.split(rng)
                    nxt = self._sample(logits, sub).astype(jnp.int32)
                    logits, state = self.lm.decode_step(params, nxt[:, None],
                                                        state)
                    return (logits, state, rng), nxt

                (logits, state, rng), toks = jax.lax.scan(
                    body, (logits, state, rng), None, length=steps)
                return toks.T, logits, state, rng

            fn = self._segments[steps] = jax.jit(seg, donate_argnums=(1, 2))
        return fn

    # ------------------------------------------------------ instrumentation
    def instrument(self, perfctr, prompt_len: int = 16) -> None:
        """Attach a PerfCtr and probe the serving regions (wrapper mode).

        Event counts for ``serve.prefill`` / ``serve.decode`` are read from
        the compiled artifacts against abstract inputs — the measured
        programs are never executed (the paper's zero-overhead claim by
        construction).  Wall-clock then accumulates into the same regions
        on every ``generate()`` / scheduler segment via ``region_timer``.
        """
        self.perfctr = perfctr
        cfg = self.cfg
        b = cfg.batch_slots
        params_s = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.params)
        state_s = jax.eval_shape(
            lambda: self.lm.init_decode_state(b, cfg.max_seq))
        toks_s = jax.ShapeDtypeStruct((b, prompt_len), jnp.int32)
        with perfctr.marker(PREFILL_REGION), self._impl_ctx():
            perfctr.probe(self.lm.prefill, params_s,
                          {"tokens": toks_s}, state_s)
        tok_s = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        with perfctr.marker(DECODE_REGION):
            perfctr.probe(self.lm.decode_step, params_s, tok_s, state_s)


class BatchScheduler:
    """True continuous batching over an Engine's shared decode state.

    A slot table of ``batch_slots`` rows.  Decode runs in jitted
    multi-token segments (``admission_chunk`` steps; never more than any
    active row's remaining budget, so no token is generated past its
    request's ``max_new_tokens``).  After each segment ONE host sync
    fetches the segment's tokens; finished rows (eos or budget) release
    their slots immediately and queued requests prefill into the freed
    slots at their exact prompt length before the next segment — no
    full-batch barrier, no wave drains.
    """

    def __init__(self, engine: Engine,
                 admission_chunk: Optional[int] = None):
        self.engine = engine
        self.admission_chunk = (admission_chunk
                                or engine.cfg.admission_chunk)
        self.queue: collections.deque = collections.deque()
        self.completed: Dict[int, Request] = {}
        self.metrics: Dict[str, float] = {"segments": 0, "admissions": 0,
                                          "decode_steps": 0}
        self.admission_log: List[Tuple[int, int]] = []   # (rid, slot)

    def submit(self, req: Request) -> None:
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1, got "
                f"{req.max_new_tokens}")
        if len(req.prompt) + req.max_new_tokens > self.engine.cfg.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt ({len(req.prompt)}) + max_new "
                f"({req.max_new_tokens}) exceeds max_seq "
                f"({self.engine.cfg.max_seq})")
        req.submit_time = time.perf_counter()
        self.queue.append(req)

    def run(self) -> Dict[int, Request]:
        eng, cfg = self.engine, self.engine.cfg
        if not self.queue:
            return self.completed
        nslots = cfg.batch_slots
        state = eng.lm.init_decode_state(nslots, cfg.max_seq)
        logits = jnp.zeros((nslots, eng.lm.cfg.vocab), eng.lm.dtype)
        rng = jax.random.PRNGKey(cfg.seed)
        slots: List[Optional[Request]] = [None] * nslots
        remaining = np.zeros(nslots, np.int64)

        while self.queue or any(s is not None for s in slots):
            # ---- admission: freed slots take queued requests mid-flight
            for i in range(nslots):
                if slots[i] is None and self.queue:
                    req = self.queue.popleft()
                    state, logits = eng.prefill_slot(state, logits,
                                                     req.prompt, i)
                    slots[i] = req
                    remaining[i] = req.max_new_tokens
                    self.metrics["admissions"] += 1
                    self.admission_log.append((req.rid, i))

            active = np.array([s is not None for s in slots])
            # largest power of two that fits every active row's remaining
            # budget: never over-generates past a request's max_new_tokens,
            # and only log2(admission_chunk)+1 distinct segment programs
            # ever compile
            fit = int(min(self.admission_chunk, remaining[active].min()))
            steps = 1 << (fit.bit_length() - 1)
            with eng._region_timer(DECODE_REGION):
                toks, logits, state, rng = eng.decode_segment(steps)(
                    eng.params, state, logits, rng)
                toks_np = eng._fetch(toks)       # ONE sync per segment
            self.metrics["segments"] += 1
            self.metrics["decode_steps"] += steps
            now = time.perf_counter()

            # ---- retire: finished rows release their slots immediately
            for i in np.nonzero(active)[0]:
                req = slots[i]
                if not req.generated and not req.first_token_time:
                    req.first_token_time = now
                take = toks_np[i]
                finished = False
                if cfg.eos_token >= 0:
                    hits = np.nonzero(take == cfg.eos_token)[0]
                    if hits.size:
                        take = take[:hits[0] + 1]
                        finished = True
                req.generated.extend(int(t) for t in take)
                remaining[i] = req.max_new_tokens - len(req.generated)
                if finished or remaining[i] <= 0:
                    req.finished = True
                    self.completed[req.rid] = req
                    slots[i] = None
                    remaining[i] = 0
        return self.completed
