"""Serving engine: on-device fused decode + true continuous batching.

The hot path runs at device speed.  Two layers:

* :class:`Engine` — the jitted compute.  ``generate()`` fuses
  prefill -> [sample -> append -> eos-mask -> decode_step]* into a single
  jitted program (``lax.while_loop`` with on-device greedy/categorical
  sampling and per-row done masking), so one call is ONE dispatch and ONE
  device->host sync regardless of how many tokens it decodes — the old
  implementation round-tripped device->host once per token.  Ragged prompts
  are first-class for attention-cache families: per-row prompt-length masks
  keep pad keys out of every softmax and each row's cache advances at its
  own position (``models/lm.py prefill(lengths=...)``).
* :class:`BatchScheduler` — true continuous batching.  A slot table over
  ONE shared decode state: decode runs in jitted multi-token *segments*
  (``admission_chunk`` steps, decode state donated segment-to-segment so
  buffers are reused, not churned); after each segment a single host sync
  fetches the segment's tokens, finished rows release their slots
  immediately, and queued requests prefill into the freed slots mid-flight
  at their EXACT prompt length (single-row prefill, no padding — which is
  also what makes recurrent-state families batch raggedly here).

Prefill attention routes through the kernel dispatch layer
(:mod:`repro.kernels.dispatch`): on TPU the Pallas flash kernel is the
prefill path; ``ServeConfig.attn_impl`` pins a named implementation for
every program an engine traces (tests force ``pallas_flash`` on CPU to
prove token-identical output through the kernel).

Every device->host transfer goes through :meth:`Engine._fetch`, so
``engine.host_syncs`` is an auditable counter — tests assert the O(1)
bound and ``benchmarks/bench_serve.py`` reports it next to tokens/s.
Instrumentation is LIKWID-style (``Engine.instrument``): event counts for
the ``serve.decode`` / ``serve.prefill`` regions come from the compiled
artifact (wrapper mode, zero overhead), wall-clock accumulates into the
same regions via ``PerfCtr.region_timer``.

``generate()`` is fully deterministic given (seed, prompts).  In the
scheduler, greedy decoding (temperature 0, the default) is replayable
per-request; with temperature > 0 one PRNG stream is shared across slots,
so a request's samples depend on what it was co-scheduled with — the
continuous-batching trade, stated here rather than hidden.
"""

from __future__ import annotations

import collections
import contextlib
import copy
import dataclasses
import time
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.lm import LM

__all__ = ["ServeConfig", "Engine", "BatchScheduler", "Request",
           "MASKED_FAMILIES"]

# families whose decode state is an attention cache: pad keys can be masked
# per row, so ragged prompts batch exactly.  Recurrent-state families
# (xlstm, hybrid) cannot un-run a pad token through a running state; they
# keep pads-as-context semantics in the static batched path and batch
# raggedly through the scheduler's exact-length slot prefill instead.
MASKED_FAMILIES = ("dense", "moe", "vlm")

PREFILL_REGION = "serve.prefill"
DECODE_REGION = "serve.decode"


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 1024
    batch_slots: int = 4
    temperature: float = 0.0        # 0 -> greedy
    # sampled decode (temperature > 0) filtering, dispatched through the
    # registry's "sampling" kernel family: top_k > 0 keeps the k best
    # logits, else top_p < 1.0 keeps the nucleus; the defaults (0, 1.0)
    # are plain categorical sampling, bit-identical to the pre-family
    # jax.random.categorical(rng, logits / temperature)
    top_k: int = 0
    top_p: float = 1.0
    eos_token: int = -1             # -1 -> never stop early
    seed: int = 0
    admission_chunk: int = 8        # decode steps between admission points
    # attention impl forced for every program this engine traces (None ->
    # repro.kernels.registry picks by backend/shape/env); fixed per-engine
    # because jitted programs are traced once and cached.  "paged_decode"
    # pins the Pallas paged kernel on the decode side and leaves prefill
    # to the heuristics.  (Legacy single-name spelling; `impls` below is
    # the general form and wins per family when both are given.)
    attn_impl: Optional[str] = None
    # per-family kernel pins through the registry's one override ladder,
    # e.g. {"attention": "pallas_flash", "paged_decode": "pallas_paged"} —
    # any registered family may appear (stream_triad, ssd_scan, ...)
    impls: Optional[Mapping[str, str]] = None
    # paged KV cache: tokens per page (0 -> dense call-sized caches).
    # Attention-cache families only; decode traffic becomes O(length).
    page_size: int = 0
    # pool capacity in pages (None -> dense worst case + segment headroom,
    # which is safe but savings-free; size from expected traffic instead)
    pool_pages: Optional[int] = None
    # paged KV storage dtype: None keeps the model dtype; "fp32"/"bf16"
    # store pages in that dtype; "int8" stores quantized codes with
    # per-token f32 scales and decodes through the q8 kernel variants.
    # Paged engines only — dense caches always keep the model dtype.
    kv_dtype: Optional[str] = None
    # shared-prefix radix cache (paged engines): admission maps already-
    # resident prefix pages into the new slot read-only (refcounted,
    # copy-on-write at the fork page) and prefills only the divergent
    # suffix — N requests sharing a prompt prefix prefill it once
    prefix_cache: bool = True


#: ServeConfig.kv_dtype vocabulary -> page storage dtype
KV_DTYPES = {"fp32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}


#: Request.status values that end a request's life (no further tokens)
TERMINAL_STATUSES = ("done", "expired", "cancelled", "shed", "rejected")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    submit_time: float = 0.0        # set by BatchScheduler.submit
    first_token_time: float = 0.0   # set when the first token reaches host
    finished: bool = False          # set by the scheduler (eos or budget)
    # ---- request-plane robustness (all optional; defaults = old behavior)
    priority: int = 1               # lower is more urgent (0 interactive,
                                    # 1 default, 2 batch); shed-lowest
                                    # evicts the worst class first
    deadline_ms: Optional[float] = None       # total wall budget from submit
    ttft_deadline_ms: Optional[float] = None  # first-token wall budget
    status: str = "new"             # new|queued|active|done|expired|
                                    # cancelled|shed|rejected
    cancel_requested: bool = False  # the cancellation token (see cancel())
    spec: bool = False              # opt this request into speculative
                                    # decoding (spec-engine schedulers only;
                                    # ignored elsewhere).  Mixed batches are
                                    # fine: spec rows commit up to K+1
                                    # tokens per segment, plain rows 1.

    def cancel(self) -> None:
        """Request-side cancellation token: the scheduler retires the row
        (or dequeues the request) at the next segment boundary; no token
        generated after the flag is observed is ever returned."""
        self.cancel_requested = True

    @property
    def terminal(self) -> bool:
        return self.status in TERMINAL_STATUSES

    @property
    def done(self) -> bool:
        return self.finished or len(self.generated) >= self.max_new_tokens

    @property
    def ttft(self) -> Optional[float]:
        """Time-to-first-token (segment-granular), None until measured."""
        if self.first_token_time and self.submit_time:
            return self.first_token_time - self.submit_time
        return None


class Engine:
    def __init__(self, lm: LM, params: Any, cfg: ServeConfig,
                 perfctr=None, mesh=None, spec=None, draft_params=None):
        """``mesh``: None (single device — the pre-mesh engine, verbatim),
        a ``jax.sharding.Mesh`` with a ``model`` axis (sharded serving),
        or a :class:`repro.launch.mesh.ServeMesh` (sharded serving PLUS
        the topology/pin/spare provenance the ft/ degradation path needs).

        Under a mesh: attention/MLP weights shard per the LM's sharding
        rules (heads/ff/vocab over ``model``), the KV cache — dense or
        paged — shards its kv-head dim over ``model`` so each device
        holds its head slice, and page tables stay host-side and global.
        The jitted programs are unchanged; GSPMD partitions them over the
        mesh, and greedy tokens stay bit-identical to the single-device
        engine (argmax picks the lowest max index regardless of vocab
        sharding).

        ``spec``: a :class:`repro.serve.spec.SpecConfig` pairing a draft
        model with this target for speculative decoding (paged engines
        only); ``draft_params`` are the draft model's weights.  Draft KV
        pages live in the same pool as the target's, in a second slot
        namespace (slot ``batch_slots + i`` mirrors target slot ``i``).
        """
        self.serve_mesh = mesh if hasattr(mesh, "topo") else None
        self.mesh = self.serve_mesh.mesh if self.serve_mesh else mesh
        if self.mesh is not None:
            if "model" not in self.mesh.axis_names:
                raise ValueError(
                    f"serving mesh needs a 'model' axis, got "
                    f"{self.mesh.axis_names}")
            msize = int(self.mesh.shape["model"])
            if lm.cfg.num_kv_heads % msize != 0:
                raise ValueError(
                    f"num_kv_heads={lm.cfg.num_kv_heads} does not divide "
                    f"over the model axis ({msize} devices) — KV-head "
                    f"sharding needs whole head slices per device")
            # private view of the LM: constrain() targets THIS engine's
            # mesh without leaking into other engines sharing the lm
            lm = copy.copy(lm)
            lm.mesh = self.mesh
        self.lm = lm
        self.params = (self._shard_params(params)
                       if self.mesh is not None else params)
        self.cfg = cfg
        self.perfctr = perfctr          # optional repro.core.perfctr.PerfCtr
        self.host_syncs = 0             # device->host transfers (audited)
        self.fused_calls = 0            # fused-loop dispatches
        self.paged = cfg.page_size > 0
        if self.paged and lm.cfg.family not in MASKED_FAMILIES:
            raise ValueError(
                f"page_size={cfg.page_size} needs an attention-cache "
                f"family ({MASKED_FAMILIES}), not {lm.cfg.family!r}")
        if cfg.impls:
            from repro.kernels import registry
            for fam, name in cfg.impls.items():
                registry.get_spec(fam, name)        # validate eagerly
        if (cfg.attn_impl == "paged_decode"
                or "paged_decode" in (cfg.impls or {})) and not self.paged:
            raise ValueError(
                "a paged_decode kernel pin was requested, but this engine "
                "is dense (page_size=0) — the pin would silently measure "
                "the dense path; set page_size too")
        self.kv_dtype = None
        if cfg.kv_dtype is not None:
            if not self.paged:
                raise ValueError(
                    f"kv_dtype={cfg.kv_dtype!r} needs a paged KV cache "
                    "(page_size > 0) — dense caches keep the model dtype")
            if cfg.kv_dtype not in KV_DTYPES:
                raise ValueError(
                    f"unknown kv_dtype {cfg.kv_dtype!r}; choose from "
                    f"{sorted(KV_DTYPES)}")
            self.kv_dtype = KV_DTYPES[cfg.kv_dtype]
        self.quantized = cfg.kv_dtype == "int8"
        if self.paged:
            # a paged_decode pin must match the page storage flavor: an fp
            # impl cannot read int8 codes and a q8 impl needs scales —
            # fail at construction instead of silently measuring the
            # wrong kernel (or crashing mid-trace)
            from repro.kernels import registry
            pin = None
            if cfg.attn_impl:
                pin = registry.LEGACY_ATTN_MAP.get(
                    cfg.attn_impl, {}).get("paged_decode")
            if cfg.impls and "paged_decode" in cfg.impls:
                pin = cfg.impls["paged_decode"]
            if pin is not None:
                pin_spec = registry.get_spec("paged_decode", pin)
                if (pin_spec.supports is not None
                        and not pin_spec.supports(quantized=self.quantized)):
                    want = ("pallas_paged_q8/jnp_paged_q8" if self.quantized
                            else "pallas_paged/jnp_paged")
                    raise ValueError(
                        f"paged_decode impl {pin!r} cannot read "
                        f"kv_dtype={cfg.kv_dtype or 'model-dtype'!r} pages; "
                        f"pin one of {want} (or drop the pin and let the "
                        f"registry heuristic pick)")
        # ---- speculative decoding: draft model riding in the same pool
        self.spec = spec
        self.draft_lm = None
        self.draft_params = None
        if spec is not None:
            spec.validate(lm.cfg, cfg)
            if self.mesh is not None:
                raise ValueError(
                    "speculative decoding on a sharded engine is not "
                    "supported yet — build the spec engine single-device")
            if draft_params is None:
                raise ValueError(
                    "Engine(spec=...) needs draft_params (the draft "
                    "model's weights)")
            self.draft_lm = LM(spec.draft_config, lm.features,
                               dtype=lm.dtype)
            self.draft_params = draft_params
        self.spec_policy = (spec.resolve_policy(cfg.temperature)
                            if spec is not None else None)
        if self.paged:
            from repro.serve import kv_pool
            # table/pool headroom: power-of-two segments may overshoot a
            # request's budget by up to one segment of writes; a spec
            # round additionally writes up to K+1 verify tokens past the
            # committed length before the rewind
            headroom = self.seg_cap
            if spec is not None:
                headroom = max(headroom, spec.num_draft_tokens + 1)
            self.table_width = kv_pool.table_width_for(
                cfg.max_seq, cfg.page_size, headroom)
            base_pages = kv_pool.recommended_pages(
                cfg.batch_slots, cfg.max_seq, cfg.page_size, headroom)
            # draft pages mirror the target's token-for-token: the second
            # namespace doubles the pool's worst case
            self.pool_pages = cfg.pool_pages or (
                2 * base_pages if spec is not None else base_pages)
        self._prefill = jax.jit(lm.prefill)
        self._decode = jax.jit(lm.decode_step)
        # fused generate programs: keyed by max_new (dense) or by
        # (max_new, pool pages, table width) (paged — pool is call-sized)
        self._fused: Dict[Any, Callable] = {}
        # continuous-batching decode segments, keyed by static step count
        # (power-of-two quantized: at most log2(admission_chunk)+1 entries)
        self._segments: Dict[int, Callable] = {}
        # slot prefill: init+prefill a single row in one jitted program
        self._slot_prefill = jax.jit(self._slot_prefill_impl)
        # slot merge: scatter a single-row state into the shared state;
        # the big buffers are donated — admission rewrites one row in place
        self._merge = jax.jit(self._merge_impl, donate_argnums=(0, 1))
        # paged slot prefill: writes the row's K/V straight into the shared
        # pool pages (no row-sized twin state to merge), donated in place
        self._paged_slot_prefill = jax.jit(self._paged_slot_prefill_impl,
                                           donate_argnums=(1, 2))
        # batched copy-on-write page copy (prefix-cache fork points)
        self._copy_pages = jax.jit(self._copy_pages_impl,
                                   donate_argnums=(0,))
        # speculative decoding programs (spec engines only): the draft
        # twin of the paged slot prefill, and the one-round spec segment
        self._draft_slot_prefill = jax.jit(self._draft_slot_prefill_impl,
                                           donate_argnums=(1,))
        self._spec_seg = None

    # -------------------------------------------------------------- helpers
    @property
    def seg_cap(self) -> int:
        """Largest power-of-two segment: quantized steps never exceed it."""
        return 1 << (max(self.cfg.admission_chunk, 1).bit_length() - 1)

    def quantize_steps(self, steps: int) -> int:
        """Round a requested step count UP to a power of two (capped at the
        admission chunk), so the scheduler's churn of distinct remaining-
        budget values compiles at most log2(chunk)+1 segment programs.
        Overshoot past a request's budget is masked by the scheduler
        against ``max_new_tokens`` — no token is ever *returned* past it.
        """
        steps = max(int(steps), 1)
        return min(1 << (steps - 1).bit_length(), self.seg_cap)

    def _state_kwargs(self) -> Dict[str, Any]:
        """init_decode_state kwargs for this engine's cache flavor."""
        if not self.paged:
            return {}
        return dict(page_size=self.cfg.page_size,
                    num_pages=self.pool_pages,
                    table_width=self.table_width,
                    kv_dtype=self.kv_dtype)

    # ------------------------------------------------------- mesh sharding
    @property
    def mesh_facts(self) -> Dict[str, Any]:
        """Sharding facts for the kernel registry's per-sharding tune keys
        (``registry.use_mesh_facts``); empty when single-device."""
        if self.mesh is None:
            return {}
        msize = int(self.mesh.shape["model"])
        kvh = self.lm.cfg.num_kv_heads
        # 0 marks an indivisible head sharding for `supports` predicates;
        # __init__ validation makes it unreachable from a live engine
        pdh = kvh // msize if kvh % msize == 0 else 0
        return dict(mesh_shape=tuple(self.mesh.devices.shape),
                    mesh_axis="model", per_device_heads=pdh)

    def _shard_params(self, params):
        from repro.models.layers import shard_params_tree
        return shard_params_tree(params, self.lm.param_specs(),
                                 self.lm.rules, self.mesh)

    def _state_spec(self, leaf) -> P:
        """PartitionSpec for one decode-state leaf: KV storage — dense
        caches [L,B,S,KVH,Dh] and paged pools [L,P,ps,KVH,Dh] alike —
        shards its kv-head dim (-2) over ``model``; page tables, lengths
        and quant scales replicate (the tables are host-planned and
        global — every device walks the same pages, reading its own head
        slice)."""
        msize = int(self.mesh.shape["model"])
        if leaf.ndim == 5 and leaf.shape[-2] % msize == 0:
            return P(None, None, None, "model", None)
        return P()

    def shard_state(self, state):
        """device_put a decode state with this engine's shardings (no-op
        single-device).  Also the re-mesh reshard path: committed arrays
        move from the old mesh to the new one."""
        if self.mesh is None:
            return state
        return jax.tree.map(
            lambda x: jax.device_put(
                x, NamedSharding(self.mesh, self._state_spec(x))), state)

    def replicate(self, x):
        """Replicate an array over the mesh (no-op single-device)."""
        if self.mesh is None:
            return x
        return jax.device_put(x, NamedSharding(self.mesh, P()))

    def _constrain_state(self, state):
        """In-program twin of :meth:`shard_state` for states created
        inside jit (fused generate, slot prefill): pins the KV layout at
        trace time so GSPMD never round-trips the pool."""
        if self.mesh is None:
            return state
        return jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, self._state_spec(x))), state)

    def apply_remesh(self, plan):
        """Rebuild the engine on an ft/ re-mesh plan (device failure).

        ``plan`` is a :class:`repro.ft.elastic.RemeshPlan`; the engine
        re-device_puts its params onto the surviving mesh and drops every
        traced program (they bake the old mesh into their shardings).
        The caller reshards any live decode state via
        :meth:`shard_state`.  Returns the new mesh.
        """
        from repro.ft import elastic
        mesh = elastic.build_mesh_from_plan(plan)
        self.mesh = mesh
        self.lm.mesh = mesh
        self.params = self._shard_params(self.params)
        self._fused.clear()
        self._segments.clear()
        self._prefill = jax.jit(self.lm.prefill)
        self._decode = jax.jit(self.lm.decode_step)
        self._slot_prefill = jax.jit(self._slot_prefill_impl)
        self._merge = jax.jit(self._merge_impl, donate_argnums=(0, 1))
        self._paged_slot_prefill = jax.jit(self._paged_slot_prefill_impl,
                                           donate_argnums=(1, 2))
        self._copy_pages = jax.jit(self._copy_pages_impl,
                                   donate_argnums=(0,))
        self._draft_slot_prefill = jax.jit(self._draft_slot_prefill_impl,
                                           donate_argnums=(1,))
        self._spec_seg = None
        return mesh

    def set_page_table(self, state, table) -> Any:
        """Swap the (host-managed) page table into a decode state."""
        caches = state["caches"]
        n_layers = caches.length.shape[0]
        tbl = jnp.broadcast_to(jnp.asarray(table, jnp.int32)[None],
                               (n_layers,) + tuple(table.shape))
        return dict(state, caches=caches._replace(page_table=tbl))

    def _fetch(self, tree):
        """THE device->host sync point: every transfer is counted here."""
        self.host_syncs += 1
        return jax.device_get(tree)

    def _region_timer(self, region: str):
        return (self.perfctr.region_timer(region) if self.perfctr is not None
                else contextlib.nullcontext())

    def _impl_ctx(self):
        """Kernel-registry override while tracing/running engine programs.

        Attention routes through repro.kernels.registry; pinning the
        config here means every program this engine traces (fused
        generate, slot prefill, reference loop, instrument probes)
        resolves to the same implementations.  The legacy single-name
        ``cfg.attn_impl`` enters first, then the per-family ``cfg.impls``
        mapping on top (inner wins per family).  A sharded engine also
        publishes its mesh facts so registry lookups (and the autotuner)
        key per sharding.
        """
        from repro.kernels import registry
        stack = contextlib.ExitStack()
        if self.cfg.attn_impl is not None:
            mapping = registry.LEGACY_ATTN_MAP.get(self.cfg.attn_impl)
            if mapping is None:
                raise ValueError(
                    f"unknown attention impl {self.cfg.attn_impl!r}; "
                    f"choose from {tuple(registry.LEGACY_ATTN_MAP)}")
            stack.enter_context(registry.use_impl(**mapping))
        if self.cfg.impls:
            stack.enter_context(registry.use_impl(**dict(self.cfg.impls)))
        if self.mesh is not None:
            stack.enter_context(registry.use_mesh_facts(**self.mesh_facts))
        return stack

    @property
    def sampling_method(self) -> str:
        """The registry "sampling" family method this engine decodes with."""
        cfg = self.cfg
        if cfg.temperature <= 0.0:
            return "greedy"
        return "top_k" if cfg.top_k else "top_p"

    def _sample(self, logits: jnp.ndarray, rng=None) -> jnp.ndarray:
        """One sampling step through the registry's "sampling" family
        (``ServeConfig.impls`` may pin an impl; the heuristic picks the
        jnp oracle on CPU, the Pallas blockwise argmax on TPU).  The
        seeded-PRNG contract keeps tokens bit-identical to the historic
        ``argmax`` / ``jax.random.categorical(rng, logits / T)``."""
        from repro.kernels import sampling
        cfg = self.cfg
        return sampling.sample(logits, rng, method=self.sampling_method,
                               temperature=max(cfg.temperature, 1e-6),
                               k=cfg.top_k, p=cfg.top_p)

    def _pad_prompts(self, prompts: Sequence[Sequence[int]]
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Right-pad to the longest prompt; per-row true lengths ride along
        (attention families mask pad keys out via batch["lengths"])."""
        maxlen = max(len(p) for p in prompts)
        toks = np.zeros((len(prompts), maxlen), np.int32)
        lens = np.array([len(p) for p in prompts], np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        return toks, lens

    # ------------------------------------------------- fused generate (jit)
    def _make_fused(self, max_new: int,
                    paged_dims: Optional[Tuple[int, int]] = None) -> Callable:
        """Build the single-dispatch generate program for a fixed budget.

        prefill + the whole decode loop live in ONE jitted computation:
        the loop body samples on device, records the token into a [B,T]
        buffer, folds eos into a per-row done mask, and early-exits the
        while_loop as soon as every row is done — zero host round-trips.

        ``paged_dims`` = (num_pages, table_width) builds the paged twin:
        the KV pool inside the program is sized to THIS call's actual
        demand (sum over rows of ceil((len+max_new)/page)), and the host-
        planned page table rides in as an argument — one long prompt no
        longer inflates every row's buffer.
        """
        cfg = self.cfg
        masked = self.lm.cfg.family in MASKED_FAMILIES

        def fused(params, toks, lens, rng, extra, table=None):
            b = toks.shape[0]
            # size the cache to THIS call's worst case, not cfg.max_seq:
            # every decode step streams the whole cache buffer, so capacity
            # the call can't reach is pure wasted traffic (rounded up so
            # nearby shapes share layouts)
            need = toks.shape[1] + max_new
            seq_cap = min(cfg.max_seq, -(-need // 32) * 32)
            if paged_dims is not None:
                num_pages, table_width = paged_dims
                state = self.lm.init_decode_state(
                    b, seq_cap, page_size=cfg.page_size,
                    num_pages=num_pages, table_width=table_width,
                    kv_dtype=self.kv_dtype)
                state = self.set_page_table(state, table)
            else:
                state = self.lm.init_decode_state(b, seq_cap)
            state = self._constrain_state(state)
            batch = dict(extra, tokens=toks)
            if masked:
                batch["lengths"] = lens
            logits, state = self.lm.prefill(params, batch, state)

            def cond(c):
                t, _rng, _logits, _state, _out, done, _n = c
                return (t < max_new) & jnp.logical_not(done.all())

            def body(c):
                t, rng, logits, state, out, done, n = c
                rng, sub = jax.random.split(rng)
                nxt = self._sample(logits, sub).astype(jnp.int32)
                emit = jnp.logical_not(done)
                out = jax.lax.dynamic_update_slice(
                    out, jnp.where(emit, nxt, 0)[:, None], (0, t))
                n = n + emit.astype(jnp.int32)
                if cfg.eos_token >= 0:
                    done = done | (emit & (nxt == cfg.eos_token))
                logits, state = self.lm.decode_step(params, nxt[:, None],
                                                    state)
                return (t + 1, rng, logits, state, out, done, n)

            carry = (jnp.zeros((), jnp.int32), rng, logits, state,
                     jnp.zeros((b, max_new), jnp.int32),
                     jnp.zeros((b,), bool), jnp.zeros((b,), jnp.int32))
            carry = jax.lax.while_loop(cond, body, carry)
            return carry[4], carry[6]           # tokens [B,T], counts [B]

        return jax.jit(fused)

    # ----------------------------------------------------------------- API
    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int = 32,
                 extra_batch: Optional[Dict[str, np.ndarray]] = None,
                 stream_cb: Optional[Callable] = None) -> List[List[int]]:
        """Static-batch generation: one dispatch, one host sync.

        ``stream_cb(row, tokens, done)`` opts into streaming: it fires
        once per row per *segment* with the newly committed tokens —
        per verified block (up to K+1 tokens) on a speculative engine,
        per token on a plain one — and trades the single host sync for
        one per segment.  Tokens delivered through the callback are the
        same stream the fused path returns.
        """
        cfg = self.cfg
        if self.spec is not None:
            extra = ({k: jnp.asarray(v) for k, v in extra_batch.items()}
                     if extra_batch else {})
            return self._generate_spec(prompts, max_new_tokens, extra,
                                       stream_cb)
        if stream_cb is not None:
            extra = ({k: jnp.asarray(v) for k, v in extra_batch.items()}
                     if extra_batch else {})
            return self._generate_stream(prompts, max_new_tokens, extra,
                                         stream_cb)
        toks, lens = self._pad_prompts(prompts)
        if toks.shape[1] + max_new_tokens > cfg.max_seq:
            raise ValueError(
                f"prompt ({toks.shape[1]}) + max_new ({max_new_tokens}) "
                f"exceeds max_seq ({cfg.max_seq})")
        extra = ({k: jnp.asarray(v) for k, v in extra_batch.items()}
                 if extra_batch else {})
        args = ()
        paged_dims = None
        if self.paged:
            # call-sized pool plan: exactly the pages this call can touch,
            # laid out row-major (rounded up so nearby calls share layouts)
            from repro.serve.kv_pool import pages_for
            per_row = [pages_for(len(p) + max_new_tokens, cfg.page_size)
                       for p in prompts]
            table_width = max(per_row)
            num_pages = -(-(1 + sum(per_row)) // 16) * 16
            table = np.zeros((len(prompts), table_width), np.int32)
            nxt = 1
            for i, npages in enumerate(per_row):
                table[i, :npages] = np.arange(nxt, nxt + npages)
                nxt += npages
            paged_dims = (num_pages, table_width)
            args = (jnp.asarray(table),)
        key = (max_new_tokens, paged_dims)
        fused = self._fused.get(key)
        if fused is None:
            fused = self._fused[key] = \
                self._make_fused(max_new_tokens, paged_dims)
        self.fused_calls += 1
        with self._region_timer(DECODE_REGION), self._impl_ctx():
            out, n = fused(self.params, jnp.asarray(toks), jnp.asarray(lens),
                           jax.random.key(cfg.seed), extra, *args)
            out_np, n_np = self._fetch((out, n))    # the ONE sync
        return [out_np[i, :n_np[i]].tolist() for i in range(len(prompts))]

    def generate_reference(self, prompts: Sequence[Sequence[int]],
                           max_new_tokens: int = 32,
                           extra_batch: Optional[Dict[str, np.ndarray]] = None
                           ) -> List[List[int]]:
        """The pre-fusion wave-mode loop: one dispatch AND one host sync
        per generated token, pads as ordinary context.

        Kept verbatim as (a) the measured baseline for
        ``benchmarks/bench_serve.py`` and (b) the semantic oracle the fused
        loop's tests compare against on equal-length prompts.
        """
        cfg = self.cfg
        toks, lens = self._pad_prompts(prompts)
        b = toks.shape[0]
        state = self.lm.init_decode_state(b, cfg.max_seq)
        batch: Dict[str, jnp.ndarray] = {"tokens": jnp.asarray(toks)}
        if extra_batch:
            batch.update({k: jnp.asarray(v) for k, v in extra_batch.items()})
        with self._impl_ctx():
            logits, state = self._prefill(self.params, batch, state)
        rng = jax.random.key(cfg.seed)
        out = [list() for _ in range(b)]
        done = np.zeros(b, bool)
        for t in range(max_new_tokens):
            rng, sub = jax.random.split(rng)
            nxt = self._sample(logits, sub)
            nxt_np = self._fetch(nxt)            # per-token sync (the point)
            for i in range(b):
                if not done[i]:
                    out[i].append(int(nxt_np[i]))
                    if cfg.eos_token >= 0 and nxt_np[i] == cfg.eos_token:
                        done[i] = True
            if done.all():
                break
            logits, state = self._decode(self.params, nxt[:, None], state)
        return out

    # ------------------------------------- continuous-batching primitives
    def _slot_prefill_impl(self, params, toks):
        """Init + prefill ONE row at its exact prompt length (no padding)."""
        state = self._constrain_state(
            self.lm.init_decode_state(1, self.cfg.max_seq))
        return self.lm.prefill(params, {"tokens": toks}, state)

    @staticmethod
    def _merge_impl(state, logits_buf, row_state, row_logits, slot):
        """Scatter a single-row (state, logits) into slot `slot`.

        Every decode-state leaf is [layers, B, ...]; the row twin is
        [layers, 1, ...] — one dynamic_update_slice along the batch axis
        per leaf, with the big buffers donated (in-place admission).
        """
        merged = jax.tree.map(
            lambda big, row: jax.lax.dynamic_update_slice_in_dim(
                big, row.astype(big.dtype), slot, axis=1),
            state, row_state)
        logits_buf = jax.lax.dynamic_update_slice_in_dim(
            logits_buf, row_logits.astype(logits_buf.dtype), slot, axis=0)
        return merged, logits_buf

    def _paged_slot_prefill_impl(self, params, state, logits_buf, toks,
                                 slot, table_row, prefix_len=None):
        """Prefill ONE row straight into the shared page pool.

        The row's pages already belong to it (the pool allocated them
        before this program runs), so there is no row-sized twin state to
        merge afterwards: prefill runs over a 1-row VIEW that shares the
        big page buffers, then the slot's table row, length and logits are
        scattered in.  ``state`` and ``logits_buf`` are donated — admission
        rewrites pages and one table row in place.

        ``prefix_len`` (traced scalar, or None for the plain program): the
        slot's table already maps a resident shared prefix of that many
        tokens; ``toks`` holds only the divergent suffix, which prefills
        at absolute positions ``prefix_len + i`` against the prefix pages
        (read-only — the token-granular scatter starts past them).
        """
        from repro.models.attention import PagedKVCache
        caches = state["caches"]
        n_layers = caches.length.shape[0]
        np_w = caches.page_table.shape[-1]
        row_view = PagedKVCache(
            k_pages=caches.k_pages, v_pages=caches.v_pages,
            page_table=jnp.broadcast_to(table_row[None, None],
                                        (n_layers, 1, np_w)),
            length=jnp.zeros((n_layers, 1), jnp.int32),
            k_scale=caches.k_scale, v_scale=caches.v_scale)
        batch = {"tokens": toks}
        if prefix_len is not None:
            batch["prefix_len"] = prefix_len[None]
        row_logits, new_row = self.lm.prefill(params, batch,
                                              {"caches": row_view})
        nc = new_row["caches"]
        new_caches = caches._replace(
            k_pages=nc.k_pages, v_pages=nc.v_pages,
            k_scale=nc.k_scale, v_scale=nc.v_scale,
            page_table=jax.lax.dynamic_update_slice_in_dim(
                caches.page_table,
                jnp.broadcast_to(table_row[None, None], (n_layers, 1, np_w)),
                slot, axis=1),
            # nc.length is the row's new total (prefix + suffix in suffix
            # mode, the prompt length otherwise)
            length=jax.lax.dynamic_update_slice_in_dim(
                caches.length, nc.length.astype(jnp.int32), slot, axis=1))
        logits_buf = jax.lax.dynamic_update_slice_in_dim(
            logits_buf, row_logits.astype(logits_buf.dtype), slot, axis=0)
        return dict(state, caches=new_caches), logits_buf

    def _copy_pages_impl(self, state, src, dst):
        """Device-side COW page copy: page ``src[i] -> dst[i]`` in every
        layer's K and V pools (and scale pools when quantized), one
        donated batched program.  (0, 0) pairs are null-page self-copies —
        harmless padding so distinct batch sizes can share a trace."""
        caches = state["caches"]

        def cp(pool):
            return (None if pool is None
                    else pool.at[:, dst].set(pool[:, src]))

        new = caches._replace(k_pages=cp(caches.k_pages),
                              v_pages=cp(caches.v_pages),
                              k_scale=cp(caches.k_scale),
                              v_scale=cp(caches.v_scale))
        return dict(state, caches=new)

    def copy_pages(self, state, pairs: Sequence[Tuple[int, int]]):
        """Run the batched COW copy for ``pairs`` of (src, dst) physical
        page ids (padded to a power of two with null-page self-copies so
        the program count stays logarithmic in batch size)."""
        if not pairs:
            return state
        n = 1 << (len(pairs) - 1).bit_length()
        arr = np.asarray(list(pairs) + [(0, 0)] * (n - len(pairs)), np.int32)
        with self._region_timer(PREFILL_REGION):
            return self._copy_pages(state, jnp.asarray(arr[:, 0]),
                                    jnp.asarray(arr[:, 1]))

    def prefill_slot(self, state, logits_buf, prompt: Sequence[int],
                     slot: int, table_row=None, prefix_len: int = 0):
        """Admission point: prefill `prompt` into slot `slot` mid-flight.

        Paged engines pass the slot's freshly-allocated ``table_row`` and
        the K/V lands directly in its pool pages; with ``prefix_len > 0``
        (prefix-cache hit) ``prompt`` is only the divergent suffix and the
        resident prefix pages are attended, not recomputed.  Dense engines
        keep the row-twin prefill + donated scatter-merge.
        """
        toks = jnp.asarray([list(prompt)], jnp.int32)
        if self.paged:
            assert table_row is not None, "paged admission needs a table row"
            pl = (jnp.asarray(prefix_len, jnp.int32) if prefix_len > 0
                  else None)
            with self._region_timer(PREFILL_REGION), self._impl_ctx():
                return self._paged_slot_prefill(
                    self.params, state, logits_buf, toks,
                    jnp.asarray(slot, jnp.int32),
                    jnp.asarray(table_row, jnp.int32), pl)
        if prefix_len:
            raise ValueError("prefix_len needs a paged engine "
                             "(dense caches hold no shared prefix)")
        with self._region_timer(PREFILL_REGION), self._impl_ctx():
            row_logits, row_state = self._slot_prefill(self.params, toks)
        return self._merge(state, logits_buf, row_state, row_logits,
                           jnp.asarray(slot, jnp.int32))

    def decode_segment(self, steps: int) -> Callable:
        """The jitted `steps`-token decode over all slots.

        ``steps`` is quantized UP to a power of two (``quantize_steps``),
        so scheduler churn across distinct remaining-budget values keeps
        at most log2(admission_chunk)+1 jitted entry points — the caller
        masks any overshoot against per-request budgets.  (On a paged
        engine each entry point additionally retraces per page-table
        WIDTH it is fed — the scheduler's live-mix buckets, x4-page
        quantized, bound that churn.)  ``lax.scan`` over the fused
        sample->decode body; decode state and the logits buffer are
        DONATED, so segment-to-segment the cache buffers alias instead of
        reallocating.  Returns (tokens [B,steps], logits, state, rng).
        """
        steps = self.quantize_steps(steps)
        fn = self._segments.get(steps)
        if fn is None:
            def seg(params, state, logits, rng):
                def body(carry, _):
                    logits, state, rng = carry
                    rng, sub = jax.random.split(rng)
                    nxt = self._sample(logits, sub).astype(jnp.int32)
                    logits, state = self.lm.decode_step(params, nxt[:, None],
                                                        state)
                    return (logits, state, rng), nxt

                (logits, state, rng), toks = jax.lax.scan(
                    body, (logits, state, rng), None, length=steps)
                return toks.T, logits, state, rng

            fn = self._segments[steps] = jax.jit(seg, donate_argnums=(1, 2))
        return fn

    # ------------------------------------------- speculative decoding (jit)
    @property
    def slot_headroom(self) -> int:
        """Tokens a slot's device length can grow past its budget in one
        segment: a quantized decode segment for plain engines, one K+1
        verify window for spec engines (rounds are the segments there)."""
        if self.spec is not None:
            return self.spec.num_draft_tokens + 1
        return self.seg_cap

    @staticmethod
    def _with_lengths(state, lengths):
        """Rewrite a paged state's per-row lengths (the rollback: rejected
        draft positions simply fall out of the attended/committed window;
        their pages are overwritten by the next round's writes)."""
        caches = state["caches"]
        new = jnp.broadcast_to(lengths[None].astype(jnp.int32),
                               caches.length.shape)
        return dict(state, caches=caches._replace(length=new))

    def _draft_slot_prefill_impl(self, dparams, dstate, toks, slot,
                                 table_row):
        """Draft twin of :meth:`_paged_slot_prefill_impl`: prefill ONE
        row's full context into the draft page namespace.  No prefix
        sharing (draft pages never enter the trie) and the logits are
        discarded — rounds derive the pending token from the carried
        TARGET logits."""
        from repro.models.attention import PagedKVCache
        caches = dstate["caches"]
        n_layers = caches.length.shape[0]
        np_w = caches.page_table.shape[-1]
        row_view = PagedKVCache(
            k_pages=caches.k_pages, v_pages=caches.v_pages,
            page_table=jnp.broadcast_to(table_row[None, None],
                                        (n_layers, 1, np_w)),
            length=jnp.zeros((n_layers, 1), jnp.int32),
            k_scale=caches.k_scale, v_scale=caches.v_scale)
        _logits, new_row = self.draft_lm.prefill(dparams, {"tokens": toks},
                                                 {"caches": row_view})
        nc = new_row["caches"]
        new_caches = caches._replace(
            k_pages=nc.k_pages, v_pages=nc.v_pages,
            k_scale=nc.k_scale, v_scale=nc.v_scale,
            page_table=jax.lax.dynamic_update_slice_in_dim(
                caches.page_table,
                jnp.broadcast_to(table_row[None, None],
                                 (n_layers, 1, np_w)),
                slot, axis=1),
            length=jax.lax.dynamic_update_slice_in_dim(
                caches.length, nc.length.astype(jnp.int32), slot, axis=1))
        return dict(dstate, caches=new_caches)

    def draft_prefill_slot(self, dstate, prompt: Sequence[int], slot: int,
                           table_row):
        """Admission hook: land ``prompt``'s draft KV in its pool pages."""
        toks = jnp.asarray([list(prompt)], jnp.int32)
        with self._region_timer(PREFILL_REGION), self._impl_ctx():
            return self._draft_slot_prefill(
                self.draft_params, dstate, toks,
                jnp.asarray(slot, jnp.int32),
                jnp.asarray(table_row, jnp.int32))

    def _spec_round(self, params, dparams, state, dstate, logits, rng,
                    spec_mask):
        """One draft -> verify -> accept -> rewind round (traced).

        Returns ``(seg [B,K+1], counts [B], logits', state', dstate',
        rng')``: ``seg[:, 0]`` is the committed pending token ``y``
        sampled from the carried logits, ``seg[:, 1:counts]`` the
        accepted draft tokens (``counts = a+1``), and ``logits'`` carries
        the next round's corrected distribution (see serve/spec.py).
        Rows with ``spec_mask=False`` force ``a = 0``: they commit
        exactly one token per round.
        """
        from repro.serve.spec import accept_speculative
        k = self.spec.num_draft_tokens
        rng, k_y, k_d, k_acc = jax.random.split(rng, 4)
        y = self._sample(logits, k_y).astype(jnp.int32)
        cur_len = state["caches"].length[0]           # [B], y not included

        def dbody(carry, _):
            cur, dstate, rng = carry
            lg, dstate = self.draft_lm.decode_step(dparams, cur[:, None],
                                                   dstate)
            rng, sub = jax.random.split(rng)
            nxt = self._sample(lg, sub).astype(jnp.int32)
            return (nxt, dstate, rng), (nxt, lg)

        # K+1 draft steps: the last one only lands d_K's KV so the draft
        # cache covers every position the rewind can keep (a = K)
        (_, dstate, _), (ds, qs) = jax.lax.scan(
            dbody, (y, dstate, k_d), None, length=k + 1)
        drafts = ds[:k].T                               # [B,K]
        qlogits = jnp.moveaxis(qs[:k], 0, 1)            # [B,K,V]
        suffix = jnp.concatenate([y[:, None], drafts], axis=1)
        # target verify: the WHOLE suffix in one multi-token segment
        # through the chunked-prefill path — K+1 next-token distributions
        # for one forward pass
        o, state = self.lm.prefill(
            params, {"tokens": suffix, "prefix_len": cur_len}, state,
            all_logits=True)                            # [B,K+1,V]
        acc, carry = accept_speculative(
            drafts, qlogits, o, k_acc, policy=self.spec_policy,
            temperature=self.cfg.temperature, spec_mask=spec_mask)
        new_len = cur_len + acc + 1
        return (suffix, acc + 1, carry,
                self._with_lengths(state, new_len),
                self._with_lengths(dstate, new_len), rng)

    def spec_segment(self) -> Callable:
        """The jitted spec segment for the scheduler: one spec round per
        dispatch, up to K+1 tokens per spec row and exactly 1 per
        non-spec row of a mixed batch.  Same donation contract as
        :meth:`decode_segment` (state, draft state and the logits buffer
        alias segment-to-segment)."""
        if self._spec_seg is None:
            def seg(params, dparams, state, dstate, logits, rng,
                    spec_mask):
                return self._spec_round(params, dparams, state, dstate,
                                        logits, rng, spec_mask)

            self._spec_seg = jax.jit(seg, donate_argnums=(2, 3, 4))
        return self._spec_seg

    def _spec_plan(self, prompts: Sequence[Sequence[int]], max_new: int):
        """Call-sized page plan for one spec namespace: every row gets
        pages for prompt + budget + the K+1 verify overshoot."""
        from repro.serve.kv_pool import pages_for
        cfg = self.cfg
        k = self.spec.num_draft_tokens
        per_row = [pages_for(len(p) + max_new + k + 1, cfg.page_size)
                   for p in prompts]
        table_width = max(per_row)
        num_pages = -(-(1 + sum(per_row)) // 16) * 16
        table = np.zeros((len(prompts), table_width), np.int32)
        nxt = 1
        for i, npg in enumerate(per_row):
            table[i, :npg] = np.arange(nxt, nxt + npg)
            nxt += npg
        return (num_pages, table_width), table

    def _make_spec_fused(self, max_new: int, paged_dims, draft_dims
                         ) -> Callable:
        """The fused speculative generate: prefill both models + the
        whole round loop in ONE jitted program (one dispatch, one sync).
        Returns (out [B,max_new], counts [B], proposed, accepted)."""
        cfg = self.cfg
        k = self.spec.num_draft_tokens

        def fused(params, dparams, toks, lens, rng, extra, table, dtable):
            b = toks.shape[0]
            need = toks.shape[1] + max_new + k + 1
            seq_cap = -(-need // 32) * 32
            num_pages, table_width = paged_dims
            state = self.lm.init_decode_state(
                b, seq_cap, page_size=cfg.page_size, num_pages=num_pages,
                table_width=table_width, kv_dtype=self.kv_dtype)
            state = self.set_page_table(state, table)
            dnum, dwidth = draft_dims
            dstate = self.draft_lm.init_decode_state(
                b, seq_cap, page_size=cfg.page_size, num_pages=dnum,
                table_width=dwidth, kv_dtype=self.kv_dtype)
            dstate = self.set_page_table(dstate, dtable)
            logits, state = self.lm.prefill(
                params, dict(extra, tokens=toks, lengths=lens), state)
            _dl, dstate = self.draft_lm.prefill(
                dparams, {"tokens": toks, "lengths": lens}, dstate)
            spec_mask = jnp.ones((b,), bool)

            def cond(c):
                return (c[0] < max_new) & jnp.logical_not(c[6].all())

            def body(c):
                t, rng, logits, state, dstate, out, done, n, prop, accn = c
                old_len = state["caches"].length[0]
                old_dlen = dstate["caches"].length[0]
                old_logits = logits
                seg, counts, logits, state, dstate, rng = self._spec_round(
                    params, dparams, state, dstate, logits, rng, spec_mask)
                emit = jnp.logical_not(done)
                j = jnp.arange(k + 1)[None, :]
                within = j < counts[:, None]
                if cfg.eos_token >= 0:
                    iseos = (seg == cfg.eos_token) & within
                    first = jnp.min(jnp.where(iseos, j, k + 1), axis=1)
                else:
                    first = jnp.full((b,), k + 1, jnp.int32)
                # tokens delivered this round: through the first eos, and
                # never past the budget
                allowed = jnp.minimum(counts, first + 1)
                inc = jnp.where(emit,
                                jnp.minimum(allowed,
                                            jnp.maximum(max_new - n, 0)),
                                0)
                valid = j < inc[:, None]
                pos = n[:, None] + j
                rows = jnp.arange(b)[:, None]
                out = out.at[rows, jnp.where(valid, pos, max_new)].set(
                    jnp.where(valid, seg, 0), mode="drop")
                n = n + inc
                done = done | (emit & ((first < counts) | (n >= max_new)))
                # freeze finished rows (their junk rounds stop moving the
                # carried logits and the committed lengths)
                state = self._with_lengths(
                    state, jnp.where(emit, state["caches"].length[0],
                                     old_len))
                dstate = self._with_lengths(
                    dstate, jnp.where(emit, dstate["caches"].length[0],
                                      old_dlen))
                logits = jnp.where(emit[:, None], logits, old_logits)
                prop = prop + jnp.where(emit & spec_mask, k, 0).sum()
                accn = accn + jnp.where(emit & spec_mask, counts - 1,
                                        0).sum()
                return (t + 1, rng, logits, state, dstate, out, done, n,
                        prop, accn)

            carry = (jnp.zeros((), jnp.int32), rng, logits, state, dstate,
                     jnp.zeros((b, max_new), jnp.int32),
                     jnp.zeros((b,), bool), jnp.zeros((b,), jnp.int32),
                     jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
            carry = jax.lax.while_loop(cond, body, carry)
            return carry[5], carry[7], carry[8], carry[9]

        return jax.jit(fused)

    def _generate_spec(self, prompts, max_new_tokens, extra, stream_cb):
        """Speculative generate: fully fused (one sync) without a
        callback, host-segmented (one sync + one ``stream_cb`` wave per
        round) with one.  ``self.spec_stats`` records the accept rate."""
        cfg = self.cfg
        toks, lens = self._pad_prompts(prompts)
        if toks.shape[1] + max_new_tokens > cfg.max_seq:
            raise ValueError(
                f"prompt ({toks.shape[1]}) + max_new ({max_new_tokens}) "
                f"exceeds max_seq ({cfg.max_seq})")
        pd, table = self._spec_plan(prompts, max_new_tokens)
        dd, dtable = self._spec_plan(prompts, max_new_tokens)
        b = len(prompts)
        rng = jax.random.key(cfg.seed)
        if stream_cb is None:
            key = ("spec", max_new_tokens, pd, dd)
            fused = self._fused.get(key)
            if fused is None:
                fused = self._fused[key] = self._make_spec_fused(
                    max_new_tokens, pd, dd)
            self.fused_calls += 1
            with self._region_timer(DECODE_REGION), self._impl_ctx():
                out, n, prop, accn = fused(
                    self.params, self.draft_params, jnp.asarray(toks),
                    jnp.asarray(lens), rng, extra, jnp.asarray(table),
                    jnp.asarray(dtable))
                out_np, n_np, prop_np, accn_np = self._fetch(
                    (out, n, prop, accn))                # the ONE sync
            self.spec_stats = dict(
                proposed=int(prop_np), accepted=int(accn_np),
                accept_rate=(float(accn_np) / max(int(prop_np), 1)))
            return [out_np[i, :n_np[i]].tolist() for i in range(b)]
        # ---- streaming: one jitted round per sync, tokens surface as
        # soon as the target verifies them (blockwise streaming contract:
        # stream_cb(row, accepted_tokens, done) once per row per round
        # that delivered tokens; host_syncs grows O(rounds))
        k = self.spec.num_draft_tokens
        pkey = ("spec_prefill", toks.shape[1], pd, dd)
        prefill = self._fused.get(pkey)
        if prefill is None:
            def _prefill(params, dparams, toks, lens, extra, tbl, dtbl):
                need = toks.shape[1] + max_new_tokens + k + 1
                seq_cap = -(-need // 32) * 32
                state = self.lm.init_decode_state(
                    b, seq_cap, page_size=cfg.page_size,
                    num_pages=pd[0], table_width=pd[1],
                    kv_dtype=self.kv_dtype)
                state = self.set_page_table(state, tbl)
                dstate = self.draft_lm.init_decode_state(
                    b, seq_cap, page_size=cfg.page_size,
                    num_pages=dd[0], table_width=dd[1],
                    kv_dtype=self.kv_dtype)
                dstate = self.set_page_table(dstate, dtbl)
                logits, state = self.lm.prefill(
                    params, dict(extra, tokens=toks, lengths=lens), state)
                _dl, dstate = self.draft_lm.prefill(
                    dparams, {"tokens": toks, "lengths": lens}, dstate)
                return logits, state, dstate

            prefill = self._fused[pkey] = jax.jit(_prefill)
        with self._region_timer(PREFILL_REGION), self._impl_ctx():
            logits, state, dstate = prefill(
                self.params, self.draft_params, jnp.asarray(toks),
                jnp.asarray(lens), extra, jnp.asarray(table),
                jnp.asarray(dtable))
        seg_fn = self.spec_segment()
        spec_mask = jnp.ones((b,), bool)
        outs: List[List[int]] = [[] for _ in range(b)]
        done = np.zeros(b, bool)
        proposed = accepted = 0
        with self._region_timer(DECODE_REGION), self._impl_ctx():
            for _round in range(max_new_tokens):
                if done.all():
                    break
                seg, counts, logits, state, dstate, rng = seg_fn(
                    self.params, self.draft_params, state, dstate, logits,
                    rng, spec_mask)
                seg_np, counts_np = self._fetch((seg, counts))
                for i in range(b):
                    if done[i]:
                        continue
                    proposed += k
                    accepted += int(counts_np[i]) - 1
                    take = seg_np[i][:counts_np[i]]
                    room = max_new_tokens - len(outs[i])
                    take = take[:room]
                    if cfg.eos_token >= 0:
                        hits = np.nonzero(take == cfg.eos_token)[0]
                        if hits.size:
                            take = take[:hits[0] + 1]
                            done[i] = True
                    outs[i].extend(int(t) for t in take)
                    if len(outs[i]) >= max_new_tokens:
                        done[i] = True
                    if take.size:
                        stream_cb(i, [int(t) for t in take], bool(done[i]))
        self.spec_stats = dict(
            proposed=proposed, accepted=accepted,
            accept_rate=accepted / max(proposed, 1))
        return outs

    def _generate_stream(self, prompts, max_new_tokens, extra, stream_cb):
        """Plain-engine streaming: the wave-mode loop with a callback per
        token (spec engines stream blockwise per verified segment).  The
        rng split schedule matches the fused loop, so the streamed tokens
        are the fused path's tokens."""
        cfg = self.cfg
        toks, lens = self._pad_prompts(prompts)
        b = toks.shape[0]
        state = self.lm.init_decode_state(b, cfg.max_seq)
        batch = dict(extra, tokens=jnp.asarray(toks))
        if self.lm.cfg.family in MASKED_FAMILIES:
            batch["lengths"] = jnp.asarray(lens)
        with self._region_timer(PREFILL_REGION), self._impl_ctx():
            logits, state = self._prefill(self.params, batch, state)
        rng = jax.random.key(cfg.seed)
        out: List[List[int]] = [list() for _ in range(b)]
        done = np.zeros(b, bool)
        with self._region_timer(DECODE_REGION), self._impl_ctx():
            for _t in range(max_new_tokens):
                rng, sub = jax.random.split(rng)
                nxt = self._sample(logits, sub)
                nxt_np = self._fetch(nxt)
                for i in range(b):
                    if done[i]:
                        continue
                    out[i].append(int(nxt_np[i]))
                    if cfg.eos_token >= 0 and nxt_np[i] == cfg.eos_token:
                        done[i] = True
                    if len(out[i]) >= max_new_tokens:
                        done[i] = True
                    stream_cb(i, [int(nxt_np[i])], bool(done[i]))
                if done.all():
                    break
                logits, state = self._decode(self.params, nxt[:, None],
                                             state)
        return out

    # ------------------------------------------------------ instrumentation
    def instrument(self, perfctr, prompt_len: int = 16) -> None:
        """Attach a PerfCtr and probe the serving regions (wrapper mode).

        Event counts for ``serve.prefill`` / ``serve.decode`` are read from
        the compiled artifacts against abstract inputs — the measured
        programs are never executed (the paper's zero-overhead claim by
        construction).  Wall-clock then accumulates into the same regions
        on every ``generate()`` / scheduler segment via ``region_timer``.
        """
        self.perfctr = perfctr
        cfg = self.cfg
        b = cfg.batch_slots
        params_s = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.params)
        state_s = jax.eval_shape(
            lambda: self.lm.init_decode_state(b, cfg.max_seq,
                                              **self._state_kwargs()))
        toks_s = jax.ShapeDtypeStruct((b, prompt_len), jnp.int32)
        with perfctr.marker(PREFILL_REGION), self._impl_ctx():
            perfctr.probe(self.lm.prefill, params_s,
                          {"tokens": toks_s}, state_s)
        tok_s = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        with perfctr.marker(DECODE_REGION):
            perfctr.probe(self.lm.decode_step, params_s, tok_s, state_s)

    def restore(self, path: str, **scheduler_kwargs) -> "BatchScheduler":
        """Rebuild a :class:`BatchScheduler` from a serving snapshot
        written by a previous run (crash recovery / planned restart).
        See :meth:`BatchScheduler.restore` for the parity contract."""
        return BatchScheduler.restore(self, path, **scheduler_kwargs)


class BatchScheduler:
    """True continuous batching over an Engine's shared decode state.

    A slot table of ``batch_slots`` rows.  Decode runs in jitted
    multi-token segments (power-of-two quantized, at most
    ``admission_chunk`` steps; a segment may overshoot the tightest
    remaining budget by a few on-device tokens, but retire masks every
    row against its own ``max_new_tokens`` — no token is ever RETURNED
    past a request's budget, and at most log2(chunk)+1 segment entry
    points ever exist, retraced per table-width bucket on paged
    engines).  After each segment ONE host sync fetches the
    segment's tokens; finished rows (eos or budget) release their slots
    immediately and queued requests prefill into the freed slots at their
    exact prompt length before the next segment — no full-batch barrier,
    no wave drains.

    On a paged engine (``ServeConfig.page_size > 0``) the scheduler also
    drives the KV pool (:class:`repro.serve.kv_pool.KVPool`): admission
    allocates exactly ``ceil(len/page)`` pages (deferring when the pool is
    full — backpressure instead of overcommit), each segment pre-extends
    active rows to cover its writes and uploads the fresh page table, and
    retirement returns the pages — one long request no longer inflates
    every slot's buffer.

    **Request-plane robustness** (the request lifecycle beyond the happy
    path):

    * admission is bounded (:class:`repro.serve.admission.AdmissionQueue`):
      ``max_queue``/``shed_policy`` shed or reject overload in O(1) with a
      structured retryable error, and a head-of-line request deferred by
      ``can_reserve`` blocks the queue after ``max_bypass`` bypasses
      instead of starving;
    * requests carry deadlines (``deadline_ms``/``ttft_deadline_ms``), a
      priority class and a cancellation token; expired or cancelled rows
      are retired at the next segment boundary — slot and pages freed
      immediately, the in-progress segment's tokens discarded, the event
      recorded in ``ft_events``;
    * :meth:`drain` stops admission and finishes in-flight rows;
      ``run(max_segments=N)`` exits early with active requests re-queued
      (progress kept) — the controlled-teardown path snapshots build on;
    * with ``snapshot_dir`` set, a crash-safe serving snapshot (queue,
      progress, pool index + page contents; see ``checkpoint/store.py``)
      is written every ``snapshot_every`` segments and at exit;
      :meth:`restore` rebuilds a scheduler from one — resident prefix
      pages resume without recompute, everything else replays from the
      prompt, and fp32 greedy tokens match an uninterrupted run;
    * a :class:`repro.ft.chaos.ChaosSchedule` passed as ``chaos`` is
      ticked every segment boundary (fault injection with invariant
      checks — see ``ft/chaos.py``).
    """

    def __init__(self, engine: Engine,
                 admission_chunk: Optional[int] = None,
                 ft_timeout_steps: int = 3, ft_confirm: int = 2,
                 straggler_threshold: float = 4.0,
                 straggler_min_ratio: float = 1.5,
                 max_queue: Optional[int] = None,
                 shed_policy: str = "reject-new",
                 max_bypass: int = 4,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: int = 0, snapshot_keep: int = 3,
                 chaos=None):
        from repro.serve.admission import AdmissionQueue
        self.engine = engine
        self.admission_chunk = (admission_chunk
                                or engine.cfg.admission_chunk)
        self.queue = AdmissionQueue(max_queue=max_queue,
                                    shed_policy=shed_policy,
                                    max_bypass=max_bypass)
        self.max_bypass = int(max_bypass)
        self.requests: Dict[int, Request] = {}   # every submitted rid
        self.completed: Dict[int, Request] = {}
        self.aborted: Dict[int, Request] = {}    # expired/cancelled/shed
        self.metrics: Dict[str, float] = {
            "segments": 0, "admissions": 0, "decode_steps": 0,
            # prefix-cache telemetry (paged engines; zero otherwise)
            "prefix_hits": 0,        # admissions with a non-empty match
            "prompt_tokens": 0,      # total prompt tokens submitted
            "prefilled_tokens": 0,   # tokens actually prefilled (suffixes)
            "pages_shared": 0,       # full prefix pages mapped read-only
            "cow_copies": 0,         # copy-on-write page copies issued
            # request-plane robustness telemetry
            "expired": 0, "cancelled": 0, "sheds": 0, "rejections": 0,
            "bypasses": 0, "snapshots": 0, "restores": 0,
        }
        if engine.spec is not None:
            # speculative decoding telemetry (accept_rate =
            # draft_accepted / draft_proposed over spec rows)
            self.metrics.update(spec_rounds=0, draft_proposed=0,
                                draft_accepted=0)
        self.admission_log: List[Tuple[int, int]] = []   # (rid, slot)
        self.pool = None    # KVPool, created per run() on paged engines
        self.draining = False
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = int(snapshot_every)
        self.snapshot_keep = int(snapshot_keep)
        self.chaos = chaos
        self._running = False
        self._wall_inflate = 1.0       # chaos slow/hung segment multiplier
        self._flap: set = set()        # devices skipping ONE heartbeat
        self._restore_index = None     # pool index payload from restore()
        # live run state (instance attrs so drain()/chaos/check() can see
        # them between segments; only meaningful while _running)
        self._slots: List[Optional[Request]] = []
        self._remaining = np.zeros(0, np.int64)
        self._slot_len = np.zeros(0, np.int64)
        # ---- ft/: per-segment heartbeats -> confirmed failure -> re-mesh
        # (degraded throughput instead of a killed run).  Heartbeats and
        # the governor are only armed on a ServeMesh-backed engine (the
        # re-mesh plan needs topology + pin provenance a bare jax Mesh
        # doesn't carry); the straggler detector watches segment walls on
        # EVERY engine so hung/slow segments surface single-device too.
        self.ft_timeout_steps = ft_timeout_steps
        self.ft_confirm = ft_confirm
        self.ft_events: List[Dict[str, Any]] = []
        self.failed: set = set()              # confirmed-dead device ids
        self._injected: List[Tuple[int, int]] = []  # (device_id, at_segment)
        self._dead: set = set()               # injected deaths now active
        from repro.ft.straggler import StragglerDetector
        self.straggler = StragglerDetector(threshold=straggler_threshold,
                                           min_ratio=straggler_min_ratio)
        self.heartbeats = None
        self.governor = None
        if engine.serve_mesh is not None:
            from repro.ft.elastic import RemeshGovernor
            from repro.ft.heartbeat import HeartbeatMonitor
            self._hb_ids: List[int] = list(engine.serve_mesh.device_ids)
            self.heartbeats = HeartbeatMonitor(
                len(self._hb_ids), timeout_steps=ft_timeout_steps)
            self.governor = RemeshGovernor(confirm_missing=ft_confirm)
            self.metrics["remeshes"] = 0

    def submit(self, req: Request) -> None:
        """Queue one request, or refuse it in O(1).

        Raises ValueError on malformed requests (unchanged) and
        :class:`repro.serve.admission.AdmissionRejected` — carrying a
        structured, usually retryable :class:`Rejection` — when the
        bounded queue refuses the arrival (``reason="queue_full"``), the
        scheduler is draining, or ``shed-lowest`` found nothing less
        urgent to evict.  A successful push may instead shed a queued
        lower-priority request; the victim lands in ``aborted`` with
        ``status="shed"`` and an ft event."""
        from repro.serve.admission import AdmissionRejected
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1, got "
                f"{req.max_new_tokens}")
        if len(req.prompt) + req.max_new_tokens > self.engine.cfg.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt ({len(req.prompt)}) + max_new "
                f"({req.max_new_tokens}) exceeds max_seq "
                f"({self.engine.cfg.max_seq})")
        req.submit_time = time.perf_counter()
        self.requests[req.rid] = req
        try:
            victim = self.queue.push(req)
        except AdmissionRejected as e:
            req.status = "rejected"
            self.metrics["rejections"] += 1
            self.ft_events.append(dict(
                type="reject", rid=req.rid, reason=e.rejection.reason,
                retryable=e.rejection.retryable,
                retry_after_s=e.rejection.retry_after_s,
                segment=int(self.metrics["segments"])))
            raise
        req.status = "queued"
        if victim is not None:
            victim.status = "shed"
            self.aborted[victim.rid] = victim
            self.metrics["sheds"] += 1
            self.ft_events.append(dict(
                type="shed", rid=victim.rid, priority=victim.priority,
                by_rid=req.rid, segment=int(self.metrics["segments"])))

    def cancel(self, rid: int) -> bool:
        """Host-side cancellation: flag ``rid`` for retirement at the next
        segment boundary (queued requests are dequeued immediately when no
        run is active).  Returns False for unknown/already-terminal rids —
        cancelling a finished request is a no-op, not an error."""
        req = self.requests.get(rid)
        if req is None or req.terminal:
            return False
        req.cancel_requested = True
        if not self._running and self.queue.remove(req):
            self._finish_abnormal(req, "cancel")
        return True

    def drain(self) -> Dict[int, Request]:
        """Graceful drain: stop admission, finish accepted work.

        Future submits are refused (``reason="draining"``, not retryable
        — the process is going away); requests already queued or
        in-flight run to completion, and with ``snapshot_dir`` set a
        final snapshot is written on exit.  Returns ``completed``."""
        self.draining = True
        self.queue.close()
        if not self._running:
            return self.run()
        return self.completed

    # --------------------------------------------- lifecycle bookkeeping
    def _expiry_reason(self, req: Request, now: float) -> Optional[str]:
        """Why ``req`` should be expired at this boundary, or None."""
        age_ms = (now - req.submit_time) * 1e3
        if req.deadline_ms is not None and age_ms > req.deadline_ms:
            return "deadline"
        if (req.ttft_deadline_ms is not None and not req.first_token_time
                and age_ms > req.ttft_deadline_ms):
            return "ttft_deadline"
        return None

    def _finish_abnormal(self, req: Request, reason: str) -> None:
        """Terminal bookkeeping for a cancelled/expired request: it never
        reaches ``completed`` and gains no further tokens (tokens already
        delivered in earlier segments stay — they were observable)."""
        req.status = "cancelled" if reason == "cancel" else "expired"
        self.aborted[req.rid] = req
        kind = "cancel" if reason == "cancel" else "expiry"
        self.metrics["cancelled" if reason == "cancel" else "expired"] += 1
        self.ft_events.append(dict(
            type=kind, rid=req.rid, reason=reason,
            generated=len(req.generated),
            segment=int(self.metrics["segments"])))

    def _release_slot(self, i: int) -> None:
        self._slots[i] = None
        self._remaining[i] = 0
        self._slot_len[i] = 0
        if self.pool is not None:
            self.pool.release(i)
            if self.engine.spec is not None:
                # the row's draft-namespace twin goes with it — a leaked
                # draft page would strand half the pool (KVPool.check()
                # audits the shared free list across both namespaces)
                self.pool.release(self.engine.cfg.batch_slots + i)

    def _sweep_queue(self, now: float) -> None:
        """Drop cancelled/expired requests before they ever prefill."""
        for req in list(self.queue.ordered()):
            reason = ("cancel" if req.cancel_requested
                      else self._expiry_reason(req, now))
            if reason:
                self.queue.remove(req)
                self._finish_abnormal(req, reason)

    def _fits(self, req: Request) -> bool:
        """Could ``req`` reserve its worst case right now?  (Resume
        requests measure prompt + progress.)"""
        if self.pool is None:
            return True
        full_len = len(req.prompt) + len(req.generated)
        worst = (full_len + (req.max_new_tokens - len(req.generated))
                 + self.engine.slot_headroom)
        _, shared = self.pool.match_prefix(req.prompt + req.generated)
        if self.engine.spec is not None:
            # spec engines admit into BOTH namespaces: the draft twin
            # reserves the same worst case with no prefix sharing
            from repro.serve.kv_pool import pages_for
            per_ns = min(pages_for(worst, self.pool.page_size),
                         self.pool.table_width)
            return (2 * per_ns - shared) <= self.pool.unpromised()
        return self.pool.can_reserve(worst, shared_pages=shared)

    def _pick_admission(self) -> Optional[Request]:
        """Next admissible queued request under the bounded-bypass rule:
        priority-FIFO order, but once the head has been bypassed
        ``max_bypass`` times the queue blocks until the head fits."""
        head = self.queue.head()
        if head is None:
            return None
        for idx, req in enumerate(self.queue.ordered()):
            if self._fits(req):
                if idx > 0:
                    self.queue.note_bypass(head)
                    self.metrics["bypasses"] += 1
                return req
            if idx == 0 and self.queue.bypasses(head) >= self.max_bypass:
                return None           # head blocked: let pages drain to it
        return None

    def check(self) -> None:
        """Scheduler-level invariants (the chaos harness calls this after
        every injected event, on top of ``KVPool.check``)."""
        live = {r.rid for r in self._slots if r is not None}
        queued = {r.rid for r in self.queue.ordered()}
        done = set(self.completed)
        dead = set(self.aborted)
        for a, b, what in ((live, queued, "active+queued"),
                           (live, done, "active+completed"),
                           (live, dead, "active+aborted"),
                           (queued, done, "queued+completed"),
                           (queued, dead, "queued+aborted"),
                           (done, dead, "completed+aborted")):
            assert not (a & b), f"request in two states ({what}): {a & b}"
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            assert req.status == "active", \
                f"slot {i}: status {req.status!r} while resident"
            assert len(req.generated) <= req.max_new_tokens, \
                f"slot {i}: generated past budget"
            if self.pool is not None:
                assert self.pool.slot_pages(i) > 0, \
                    f"slot {i}: active with no pages"
                if self.engine.spec is not None:
                    ds = self.engine.cfg.batch_slots + i
                    assert self.pool.slot_pages(ds) > 0, \
                        f"slot {i}: active with no draft pages"
        for rid in done:
            assert self.completed[rid].status == "done", \
                f"completed request {rid} has status " \
                f"{self.completed[rid].status!r}"
        if self.pool is not None:
            self.pool.check()

    # ------------------------------------------------ crash-safe snapshots
    @staticmethod
    def _req_to_dict(req: Request) -> Dict[str, Any]:
        return dict(rid=req.rid, prompt=list(req.prompt),
                    generated=list(req.generated),
                    max_new_tokens=req.max_new_tokens,
                    priority=req.priority, deadline_ms=req.deadline_ms,
                    ttft_deadline_ms=req.ttft_deadline_ms,
                    status=req.status, finished=req.finished,
                    spec=req.spec)

    @staticmethod
    def _req_from_dict(d: Dict[str, Any]) -> Request:
        return Request(rid=int(d["rid"]), prompt=list(d["prompt"]),
                       generated=list(d["generated"]),
                       max_new_tokens=int(d["max_new_tokens"]),
                       priority=int(d.get("priority", 1)),
                       deadline_ms=d.get("deadline_ms"),
                       ttft_deadline_ms=d.get("ttft_deadline_ms"),
                       status=str(d.get("status", "queued")),
                       finished=bool(d.get("finished", False)),
                       spec=bool(d.get("spec", False)))

    def _snapshot_config(self) -> Dict[str, Any]:
        cfg = self.engine.cfg
        return dict(max_seq=cfg.max_seq, batch_slots=cfg.batch_slots,
                    temperature=cfg.temperature, eos_token=cfg.eos_token,
                    seed=cfg.seed, page_size=cfg.page_size,
                    kv_dtype=cfg.kv_dtype, prefix_cache=cfg.prefix_cache,
                    pool_pages=(self.engine.pool_pages
                                if self.engine.paged else None),
                    vocab=self.engine.lm.cfg.vocab,
                    spec=(self.engine.spec.signature()
                          if self.engine.spec is not None else None))

    def _export_index(self, state) -> Optional[Dict[str, Any]]:
        """Serialize the prefix trie + its device page CONTENTS — the
        part of the KV state a restore can reuse without recompute."""
        if self.pool is None or not self.engine.cfg.prefix_cache:
            return None
        nodes = self.pool.export_index()
        if not nodes:
            return None
        ids = [n["page"] for n in nodes]
        caches = state["caches"]
        idx = jnp.asarray(np.asarray(ids, np.int32))
        fetch = {"k": caches.k_pages[:, idx], "v": caches.v_pages[:, idx]}
        if caches.k_scale is not None:
            fetch["k_scale"] = caches.k_scale[:, idx]
            fetch["v_scale"] = caches.v_scale[:, idx]
        host = self.engine._fetch(fetch)     # audited device->host sync
        pages = {k: np.asarray(v) for k, v in host.items()}
        pages["ids"] = ids
        return {"nodes": nodes, "pages": pages}

    def _write_snapshot(self, state, reason: str = "interval"
                        ) -> Optional[str]:
        """Atomically persist the request plane (see checkpoint/store.py
        ``save_serving_snapshot``): every non-terminal request with its
        progress, completed/aborted outcomes, metrics/events, and the
        reusable prefix-page contents.  Crash-safe by construction —
        write-temp + rename + CRC, the previous snapshot survives a
        mid-write kill."""
        if not self.snapshot_dir:
            return None
        import os

        from repro.checkpoint import store
        seg = int(self.metrics["segments"])
        # pending order: in-flight first (by admission order), then queue
        order = {rid: k for k, (rid, _s) in enumerate(self.admission_log)}
        inflight = sorted((r for r in self._slots if r is not None),
                          key=lambda r: order.get(r.rid, 0))
        pending = [self._req_to_dict(r)
                   for r in list(inflight) + list(self.queue.ordered())]
        payload = dict(
            config=self._snapshot_config(), segment=seg, reason=reason,
            pending=pending,
            completed=[self._req_to_dict(r)
                       for r in self.completed.values()],
            aborted=[self._req_to_dict(r) for r in self.aborted.values()],
            metrics=dict(self.metrics), ft_events=list(self.ft_events),
            index=self._export_index(state) if state is not None else None)
        path = os.path.join(self.snapshot_dir, f"snap_{seg:08d}.snap")
        store.save_serving_snapshot(path, payload)
        self.metrics["snapshots"] += 1
        self.ft_events.append(dict(
            type="snapshot", segment=seg, path=path, reason=reason,
            pending=len(pending)))
        for old in store.list_snapshots(
                self.snapshot_dir)[:-self.snapshot_keep]:
            try:
                os.unlink(old)
            except OSError:
                pass
        return path

    @classmethod
    def restore(cls, engine: Engine, path: str, **kwargs
                ) -> "BatchScheduler":
        """Rebuild a scheduler from a serving snapshot.

        Non-terminal requests re-queue with their progress: at admission
        each replays ``prompt + generated`` through prefill — hitting the
        restored prefix-page index for everything the snapshot retained
        (those tokens never recompute), replaying from the prompt for the
        rest — then decodes its remaining budget.  fp32 greedy tokens are
        bit-identical to an uninterrupted run.  Completed/aborted
        outcomes are pre-populated; deadlines restart from restore time
        (wall clocks don't survive a process).

        Raises :class:`repro.checkpoint.SnapshotCorrupt` on a damaged
        file and ValueError when the snapshot's engine config is
        incompatible (different ``max_seq``/``page_size``/sampling — the
        tokens could not match).  A pool-size mismatch only drops the
        page index (replay instead of resume)."""
        from repro.checkpoint import store
        snap = store.load_serving_snapshot(path)
        sc = snap.get("config", {})
        cfg = engine.cfg
        for key, actual in (("max_seq", cfg.max_seq),
                            ("page_size", cfg.page_size),
                            ("temperature", cfg.temperature),
                            ("eos_token", cfg.eos_token),
                            ("seed", cfg.seed),
                            ("vocab", engine.lm.cfg.vocab)):
            if sc.get(key) != actual:
                raise ValueError(
                    f"snapshot {path}: config mismatch on {key!r} "
                    f"(snapshot {sc.get(key)!r} != engine {actual!r})")
        snap_spec = sc.get("spec")
        eng_spec = (engine.spec.signature() if engine.spec is not None
                    else None)
        if ((tuple(snap_spec) if snap_spec else None)
                != (tuple(eng_spec) if eng_spec else None)):
            raise ValueError(
                f"snapshot {path}: config mismatch on 'spec' "
                f"(snapshot {snap_spec!r} != engine {eng_spec!r}) — "
                f"restoring under a different draft pairing could not "
                f"reproduce the token stream")
        sched = cls(engine, **kwargs)
        now = time.perf_counter()
        for d in snap.get("completed", []):
            req = cls._req_from_dict(d)
            sched.completed[req.rid] = req
            sched.requests[req.rid] = req
        for d in snap.get("aborted", []):
            req = cls._req_from_dict(d)
            sched.aborted[req.rid] = req
            sched.requests[req.rid] = req
        pending = [cls._req_from_dict(d) for d in snap.get("pending", [])]
        for req in reversed(pending):
            req.status = "queued"
            req.submit_time = now
            sched.requests[req.rid] = req
            sched.queue.push_front(req)
        index = snap.get("index")
        if index and engine.paged and (
                sc.get("pool_pages") != engine.pool_pages
                or not cfg.prefix_cache):
            index = None                  # page ids invalid: full replay
        sched._restore_index = index if engine.paged else None
        sched.metrics["restores"] += 1
        sched.ft_events.append(dict(
            type="restore", path=path,
            snapshot_segment=int(snap.get("segment", 0)),
            pending=len(pending),
            index_pages=(len(index["pages"]["ids"]) if index else 0)))
        return sched

    def _apply_restore_index(self, state):
        """Adopt the snapshot's prefix trie into the fresh pool and write
        the saved page contents back into the device state."""
        index, self._restore_index = self._restore_index, None
        if not index or self.pool is None:
            return state
        adopted = self.pool.adopt_index(index["nodes"])
        if not adopted:
            return state
        pages = index["pages"]
        idx = jnp.asarray(np.asarray(pages["ids"], np.int32))
        caches = state["caches"]

        def put(pool_arr, vals):
            if pool_arr is None or vals is None:
                return pool_arr
            return pool_arr.at[:, idx].set(
                jnp.asarray(vals).astype(pool_arr.dtype))

        caches = caches._replace(
            k_pages=put(caches.k_pages, pages.get("k")),
            v_pages=put(caches.v_pages, pages.get("v")),
            k_scale=put(caches.k_scale, pages.get("k_scale")),
            v_scale=put(caches.v_scale, pages.get("v_scale")))
        return self.engine.shard_state(dict(state, caches=caches))

    # ------------------------------------------------ ft/: degradation path
    def inject_failure(self, device_id: int, at_segment: int = 0) -> None:
        """Simulate device death: heartbeats from ``device_id`` stop once
        ``at_segment`` segments have completed.  Detection, flap-suppressed
        confirmation and the re-mesh then run exactly as they would for a
        real failure — this is the test/bench hook for the degradation
        path, not a separate code path."""
        if self.heartbeats is None:
            raise RuntimeError(
                "inject_failure needs a ServeMesh-backed engine "
                "(Engine(..., mesh=make_serve_mesh(...)))")
        self._injected.append((int(device_id), int(at_segment)))

    def _ft_tick(self, state, logits, rng, seg_wall: float):
        """One fault-tolerance observation per decode segment."""
        seg = int(self.metrics["segments"])
        for dev, at in list(self._injected):
            if seg >= at:
                self._dead.add(dev)
                self._injected.remove((dev, at))
        for idx, dev in enumerate(self._hb_ids):
            # a flapping device misses exactly ONE heartbeat (chaos
            # injection); the governor's confirm window must absorb it
            if dev not in self._dead and dev not in self._flap:
                self.heartbeats.report(idx, seg, seg_wall)
        self._flap.clear()
        missing = {self._hb_ids[i]
                   for i in self.heartbeats.missing_hosts()}
        confirmed = self.governor.observe(missing=missing)
        if confirmed:
            state, logits, rng = self._do_remesh(confirmed, state,
                                                 logits, rng)
        return state, logits, rng

    def _do_remesh(self, fresh_failures, state, logits, rng):
        """Degrade onto the survivors: plan against the skip/hot-spare
        mask, rebuild the engine's sharded programs on the reduced mesh,
        and move the LIVE decode state over — in-flight requests keep
        their KV and finish on the new mesh."""
        from repro.ft import elastic
        from repro.ft.heartbeat import HeartbeatMonitor
        eng = self.engine
        self.failed |= set(fresh_failures)
        t0 = time.perf_counter()
        axis_names = tuple(eng.mesh.axis_names)
        axis_sizes = tuple(int(eng.mesh.shape[a]) for a in axis_names)
        # model degree is pinned (param shardings stay valid); shrink the
        # first non-model axis when the spares run out
        shrink = next((a for a in axis_names if a != "model"),
                      axis_names[0])
        plan = elastic.plan_remesh(
            eng.serve_mesh.topo, sorted(self.failed),
            axis_names, axis_sizes, shrink_axis=shrink,
            strategy=eng.serve_mesh.pin.strategy)
        eng.apply_remesh(plan)
        state = eng.shard_state(state)
        logits = eng.replicate(logits)
        rng = eng.replicate(rng)
        latency = time.perf_counter() - t0
        self._hb_ids = list(plan.device_ids)
        self.heartbeats = HeartbeatMonitor(
            len(self._hb_ids), timeout_steps=self.ft_timeout_steps)
        self.metrics["remeshes"] += 1
        self.ft_events.append(dict(
            type="remesh", segment=int(self.metrics["segments"]),
            failed=sorted(self.failed),
            remesh_latency_s=latency,
            axis_sizes=list(plan.axis_sizes),
            device_ids=list(plan.device_ids),
            spares=[int(d) for d in plan.dropped
                    if d not in self.failed]))
        return state, logits, rng

    def _requeue_active(self) -> int:
        """Push every in-flight request back onto the queue with its
        progress (earliest-admitted ends up at the head), releasing slots
        and pages — the ``run(max_segments=...)`` early-exit path."""
        order = {rid: k for k, (rid, _s) in enumerate(self.admission_log)}
        live = [(order.get(r.rid, 0), i, r)
                for i, r in enumerate(self._slots) if r is not None]
        for _, i, req in sorted(live, reverse=True):
            self._release_slot(int(i))
            req.status = "queued"
            self.queue.push_front(req)
        return len(live)

    def run(self, max_segments: Optional[int] = None) -> Dict[int, Request]:
        """Drive the queue to completion (or for ``max_segments`` decode
        segments — in-flight requests then re-queue with their progress
        kept, and with ``snapshot_dir`` set an exit snapshot is written:
        the controlled half of the kill-and-restore story)."""
        eng, cfg = self.engine, self.engine.cfg
        if not self.queue:
            return self.completed
        nslots = cfg.batch_slots
        if eng.paged:
            from repro.serve.kv_pool import KVPool
            # spec engines run TWO page namespaces over one free list:
            # pool slot i is row i's target pages, slot nslots+i its
            # draft pages (never indexed in the prefix trie)
            pool_slots = 2 * nslots if eng.spec is not None else nslots
            self.pool = KVPool(eng.pool_pages, cfg.page_size, pool_slots,
                               eng.table_width,
                               prefix_cache=cfg.prefix_cache)
        state = eng.shard_state(eng.lm.init_decode_state(
            nslots, cfg.max_seq, **eng._state_kwargs()))
        dstate = None
        if eng.spec is not None:
            dstate = eng.draft_lm.init_decode_state(
                nslots, cfg.max_seq, **eng._state_kwargs())
        logits = eng.replicate(
            jnp.zeros((nslots, eng.lm.cfg.vocab), eng.lm.dtype))
        rng = eng.replicate(jax.random.key(cfg.seed))
        state = self._apply_restore_index(state)
        slots = self._slots = [None] * nslots
        remaining = self._remaining = np.zeros(nslots, np.int64)
        # device-side row length (includes segment overshoot the request
        # never sees — the page a token was WRITTEN to must stay covered)
        slot_len = self._slot_len = np.zeros(nslots, np.int64)
        self._running = True
        seg_run = 0     # segments executed by THIS call (max_segments)

        try:
            while self.queue or any(s is not None for s in slots):
                now = time.perf_counter()
                # cancelled/expired requests never reach a slot
                self._sweep_queue(now)
                # ---- admission: freed slots take queued requests
                # mid-flight, in (priority, arrival) order with bounded
                # head-of-line bypass
                width_restored = False
                for i in range(nslots):
                    if slots[i] is not None:
                        continue
                    req = self._pick_admission()
                    if req is None:
                        break
                    full = list(req.prompt) + list(req.generated)
                    budget = req.max_new_tokens - len(req.generated)
                    table_row = None
                    prefix_len = 0
                    cow_pairs: List[Tuple[int, int]] = []
                    if self.pool is not None:
                        # admission allocates exactly ceil(len/page) pages
                        # for the context (minus full-page prefix hits,
                        # which map read-only by refcount bump) and
                        # RESERVES the request's worst case (budget +
                        # segment overshoot), so decode growth can never
                        # exhaust the pool mid-run.  (_pick_admission
                        # already proved can_reserve for this request.)
                        worst = len(full) + budget + eng.slot_headroom
                        admit = self.pool.admit_prefix(i, full)
                        prefix_len = admit.matched_len
                        if admit.cow is not None:
                            cow_pairs.append(admit.cow)
                        self.pool.reserve(i, worst)
                        self.pool.alloc(i, len(full))
                        table_row = self.pool.tables[i]
                        if eng.spec is not None:
                            # the draft twin: full context, no sharing
                            self.pool.reserve(nslots + i, worst)
                            self.pool.alloc(nslots + i, len(full))
                        # admission programs key on the FULL table width
                        # (prefill only scatter-writes through the table,
                        # and writes its own slot's row on device; one
                        # width-restoring upload per round suffices — the
                        # next segment re-slices to the live mix)
                        if not width_restored:
                            tbl = self.pool.table()
                            state = eng.set_page_table(state,
                                                       tbl[:nslots])
                            if eng.spec is not None:
                                dstate = eng.set_page_table(dstate,
                                                            tbl[nslots:])
                            width_restored = True
                        # the fork page must hold the shared tokens before
                        # the suffix prefill reads (and partially rewrites)
                        # it — the copy is issued first, device-ordered
                        state = eng.copy_pages(state, cow_pairs)
                        self.metrics["prefix_hits"] += int(prefix_len > 0)
                        self.metrics["pages_shared"] += admit.shared_full
                        self.metrics["cow_copies"] += len(cow_pairs)
                    self.queue.remove(req)
                    # resume path (restore / max_segments re-queue):
                    # ``full`` replays prompt + progress through prefill —
                    # resident prefix pages are attended, not recomputed —
                    # and the row decodes only its remaining budget
                    state, logits = eng.prefill_slot(
                        state, logits, full[prefix_len:], i,
                        table_row=table_row, prefix_len=prefix_len)
                    if eng.spec is not None:
                        dstate = eng.draft_prefill_slot(
                            dstate, full, i,
                            self.pool.tables[nslots + i])
                    if self.pool is not None:
                        # index the now-resident context pages so the
                        # NEXT admission can share them
                        self.pool.register_prefix(i, full)
                    req.status = "active"
                    slots[i] = req
                    remaining[i] = budget
                    slot_len[i] = len(full)
                    self.metrics["admissions"] += 1
                    self.metrics["prompt_tokens"] += len(full)
                    self.metrics["prefilled_tokens"] += (len(full)
                                                         - prefix_len)
                    self.admission_log.append((req.rid, i))

                active = np.array([s is not None for s in slots])
                if not active.any():
                    if not self.queue:
                        break
                    head = self.queue.head()
                    if self.pool is not None and self.pool.seized:
                        # chaos pool exhaustion starved admission dry:
                        # return the seized pages rather than deadlock
                        freed = self.pool.unseize()
                        self.ft_events.append(dict(
                            type="pool_relief", pages=freed,
                            segment=int(self.metrics["segments"])))
                        continue
                    raise RuntimeError(
                        f"request {head.rid}: needs more pages than the "
                        f"whole pool can promise ({self.pool!r})")
                # requested steps fit the tightest active budget; the
                # engine quantizes UP to a power of two (so at most
                # log2(chunk)+1 segment programs ever compile) and
                # overshoot is masked against each request's budget at
                # retire time
                if eng.spec is not None:
                    # one spec round per segment: every row's device
                    # length can grow by up to K+1 (exactly `counts[i]`,
                    # fetched below); cover BOTH namespaces first
                    grow = eng.spec.num_draft_tokens + 1
                    for i in np.nonzero(active)[0]:
                        self.pool.ensure(int(i), int(slot_len[i]) + grow)
                        self.pool.ensure(nslots + int(i),
                                         int(slot_len[i]) + grow)
                    width = max(max(self.pool.slot_pages(int(i)),
                                    self.pool.slot_pages(nslots + int(i)))
                                for i in np.nonzero(active)[0])
                    bucket = min(-(-max(width, 1) // 4) * 4,
                                 eng.table_width)
                    tbl = self.pool.table()
                    state = eng.set_page_table(
                        state, tbl[:nslots, :bucket])
                    dstate = eng.set_page_table(
                        dstate, tbl[nslots:, :bucket])
                    spec_mask = jnp.asarray(
                        [s is not None and s.spec for s in slots])
                    seg_t0 = time.perf_counter()
                    with eng._region_timer(DECODE_REGION):
                        (toks, counts, logits, state, dstate,
                         rng) = eng.spec_segment()(
                            eng.params, eng.draft_params, state, dstate,
                            logits, rng, spec_mask)
                        # ONE sync per segment
                        toks_np, counts_np = eng._fetch((toks, counts))
                    produced = counts_np.astype(np.int64)
                    slot_len[active] += produced[active]
                    self.metrics["segments"] += 1
                    self.metrics["decode_steps"] += 1
                    self.metrics["spec_rounds"] += 1
                    for i in np.nonzero(active)[0]:
                        if slots[i] is not None and slots[i].spec:
                            self.metrics["draft_proposed"] += \
                                eng.spec.num_draft_tokens
                            self.metrics["draft_accepted"] += \
                                int(produced[i]) - 1
                else:
                    steps = eng.quantize_steps(
                        min(self.admission_chunk,
                            int(remaining[active].min())))
                    if self.pool is not None:
                        # cover every page this segment can write, then
                        # hand the device a table sliced to the width the
                        # LIVE mix needs (quantized so programs are
                        # shared): decode traffic — and the traffic
                        # model's gather window — tracks actual context,
                        # not max_seq.  A long request widens segments
                        # only while it is resident.
                        for i in np.nonzero(active)[0]:
                            self.pool.ensure(int(i),
                                             int(slot_len[i]) + steps)
                        width = max(self.pool.slot_pages(int(i))
                                    for i in np.nonzero(active)[0])
                        bucket = min(-(-max(width, 1) // 4) * 4,
                                     eng.table_width)
                        state = eng.set_page_table(
                            state, self.pool.table()[:, :bucket])
                    seg_t0 = time.perf_counter()
                    with eng._region_timer(DECODE_REGION):
                        toks, logits, state, rng = eng.decode_segment(
                            steps)(eng.params, state, logits, rng)
                        toks_np = eng._fetch(toks)  # ONE sync per segment
                    produced = np.full(nslots, steps, np.int64)
                    slot_len[active] += steps
                    self.metrics["segments"] += 1
                    self.metrics["decode_steps"] += steps
                seg_run += 1
                now = time.perf_counter()
                # chaos slow/hung-segment injection inflates the OBSERVED
                # wall (the detector path under test) without sleeping
                seg_wall = (now - seg_t0) * self._wall_inflate
                self._wall_inflate = 1.0
                # the straggler detector watches segment walls on EVERY
                # engine (hung/slow segments surface single-device too)
                verdict = self.straggler.record(seg_wall)
                if verdict.is_straggler:
                    self.ft_events.append(dict(
                        type="straggler",
                        segment=int(self.metrics["segments"]),
                        wall_s=seg_wall, ema_s=verdict.ema))
                if self.heartbeats is not None:
                    state, logits, rng = self._ft_tick(state, logits, rng,
                                                       seg_wall)

                # ---- retire: finished/expired/cancelled rows release
                # their slots immediately
                for i in np.nonzero(active)[0]:
                    req = slots[i]
                    reason = ("cancel" if req.cancel_requested
                              else self._expiry_reason(req, now))
                    if reason:
                        # the in-progress segment's tokens are DISCARDED:
                        # nothing generated after the flag/deadline was
                        # observed is ever returned
                        self._release_slot(int(i))
                        self._finish_abnormal(req, reason)
                        continue
                    if not req.generated and not req.first_token_time:
                        req.first_token_time = now
                    # mask overshoot: at most this segment's real tokens
                    # (spec rows: the accepted count), never past budget
                    take = toks_np[i][:min(produced[i], remaining[i])]
                    finished = False
                    if cfg.eos_token >= 0:
                        hits = np.nonzero(take == cfg.eos_token)[0]
                        if hits.size:
                            take = take[:hits[0] + 1]
                            finished = True
                    req.generated.extend(int(t) for t in take)
                    remaining[i] = req.max_new_tokens - len(req.generated)
                    if finished or remaining[i] <= 0:
                        req.finished = True
                        req.status = "done"
                        self.completed[req.rid] = req
                        self._release_slot(int(i))
                        self.queue.note_service_time(now - req.submit_time)

                if (self.snapshot_dir and self.snapshot_every
                        and int(self.metrics["segments"])
                        % self.snapshot_every == 0):
                    self._write_snapshot(state)
                if self.chaos is not None:
                    self.chaos.tick(self, int(self.metrics["segments"]))
                if max_segments is not None and seg_run >= max_segments:
                    break
        finally:
            self._running = False
        requeued = self._requeue_active()
        if self.snapshot_dir:
            self._write_snapshot(
                state, reason="exit" if not requeued else "early_exit")
        return self.completed
