from repro.serve.admission import (SHED_POLICIES, AdmissionQueue,  # noqa
                                   AdmissionRejected, Rejection)
from repro.serve.engine import (MASKED_FAMILIES, TERMINAL_STATUSES,  # noqa
                                BatchScheduler, Engine, Request,
                                ServeConfig)
from repro.serve.kv_pool import KVPool  # noqa
