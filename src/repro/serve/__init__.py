from repro.serve.engine import (MASKED_FAMILIES, BatchScheduler,  # noqa
                                Engine, Request, ServeConfig)
from repro.serve.kv_pool import KVPool  # noqa
