from repro.serve.engine import (BatchScheduler, Engine, Request,  # noqa
                                ServeConfig)
