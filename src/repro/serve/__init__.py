from repro.serve.engine import (MASKED_FAMILIES, BatchScheduler,  # noqa
                                Engine, Request, ServeConfig)
