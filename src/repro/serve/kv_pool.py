"""Host-side page allocator for the paged KV cache (the pool manager).

The device state (:class:`repro.models.attention.PagedKVCache`) is dumb
storage: a pool of ``[num_pages, page_size, KVH, Dh]`` pages per layer and
per-slot page tables.  THIS class owns the policy: a global free list of
physical pages, per-slot ownership, and the ``[slots, max_pages]`` int32
table mirror the scheduler uploads before every decode segment.

Contract (asserted by :meth:`check`, tested under scheduler churn):

* physical page 0 is the NULL page — never allocated, the landing zone
  for every unallocated table entry's (masked, unread) traffic;
* admission allocates exactly ``ceil(len/page_size)`` pages for the
  prompt and RESERVES the slot's worst-case growth (:meth:`reserve`) so
  decode-time :meth:`ensure` calls can never exhaust the pool mid-run —
  a request that cannot reserve simply waits in the queue (backpressure,
  not a mid-flight abort);
* decode growth (:meth:`ensure`) adds pages one boundary at a time;
  retirement (:meth:`release`) returns every page AND the reservation;
* a page is owned by at most one slot at a time (no double-alloc, no
  double-free), and ``free + owned == all pages`` at every step.

Sizing: :func:`recommended_pages` provisions the dense worst case plus
segment-overshoot headroom — safe but savings-free.  Real deployments set
``ServeConfig.pool_pages`` from expected traffic (mean context, not
``max_seq``); the pool then admission-gates when fragmentation would
otherwise overcommit, which is the scheduler's backpressure signal.
"""

from __future__ import annotations

import collections
from typing import Deque, List

import numpy as np

__all__ = ["KVPool", "pages_for", "recommended_pages", "table_width_for"]


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` tokens: ceil(tokens / page_size)."""
    return -(-tokens // page_size)


def table_width_for(max_seq: int, page_size: int, headroom: int = 0) -> int:
    """Logical pages per slot: ceil((max_seq + headroom) / page_size).

    ``headroom`` covers decode-segment overshoot (power-of-two quantized
    segments may write up to a segment past a request's budget)."""
    return pages_for(max_seq + headroom, page_size)


def recommended_pages(slots: int, max_seq: int, page_size: int,
                      headroom: int = 0) -> int:
    """Worst-case pool size: every slot at max_seq (+headroom), plus the
    null page.  A safe default — pools sized below it are the point."""
    return slots * table_width_for(max_seq, page_size, headroom) + 1


class KVPool:
    """Global free list + per-slot page tables over a fixed page pool."""

    def __init__(self, num_pages: int, page_size: int, slots: int,
                 table_width: int):
        if num_pages < 2:
            raise ValueError(f"pool needs >= 2 pages (got {num_pages}): "
                             "page 0 is reserved as the null page")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.slots = int(slots)
        self.table_width = int(table_width)
        # LIFO free list: recently-released pages are re-used first (their
        # contents are dead anyway and they are likelier cache-warm)
        self.free: Deque[int] = collections.deque(range(1, num_pages))
        self.owned: List[List[int]] = [[] for _ in range(slots)]
        self.reserved: List[int] = [0] * slots   # worst-case pages promised
        self.tables = np.zeros((slots, table_width), np.int32)
        self.allocs = 0          # pages handed out (audited)
        self.releases = 0        # pages returned

    # ------------------------------------------------------------- queries
    def available(self) -> int:
        return len(self.free)

    def unpromised(self) -> int:
        """Free pages not already promised to active slots' future growth."""
        outstanding = sum(max(r - len(o), 0)
                          for r, o in zip(self.reserved, self.owned))
        return len(self.free) - outstanding

    def can_fit(self, tokens: int, slot: int) -> bool:
        """Would :meth:`ensure` for ``tokens`` total tokens succeed?"""
        need = pages_for(tokens, self.page_size) - len(self.owned[slot])
        return need <= len(self.free)

    def can_reserve(self, worst_tokens: int) -> bool:
        """Could a NEW slot reserving ``worst_tokens`` of growth be
        admitted without ever failing an :meth:`ensure` later?"""
        need = min(pages_for(worst_tokens, self.page_size),
                   self.table_width)
        return need <= self.unpromised()

    def reserve(self, slot: int, worst_tokens: int) -> None:
        """Promise ``worst_tokens`` of total coverage to ``slot`` — gated
        by :meth:`can_reserve` at admission, so every later ensure() up
        to the reservation is guaranteed to find free pages."""
        self.reserved[slot] = min(pages_for(worst_tokens, self.page_size),
                                  self.table_width)

    def slot_pages(self, slot: int) -> int:
        return len(self.owned[slot])

    def table(self) -> np.ndarray:
        """A copy of the [slots, table_width] table for device upload."""
        return self.tables.copy()

    # ----------------------------------------------------------- lifecycle
    def ensure(self, slot: int, tokens: int) -> int:
        """Grow slot ``slot`` to cover ``tokens`` total tokens; returns the
        number of pages newly allocated.  Raises on pool exhaustion or
        table overflow — the scheduler admission-gates so decode-time
        growth never fails in a correctly-sized deployment."""
        need = pages_for(tokens, self.page_size)
        if need > self.table_width:
            raise ValueError(
                f"slot {slot}: {tokens} tokens need {need} pages "
                f"> table_width {self.table_width}")
        grow = need - len(self.owned[slot])
        if grow > len(self.free):
            raise RuntimeError(
                f"KV pool exhausted: slot {slot} needs {grow} more pages, "
                f"{len(self.free)} free of {self.num_pages - 1} "
                "(size the pool with ServeConfig.pool_pages)")
        for _ in range(max(grow, 0)):
            pid = self.free.pop()
            self.tables[slot, len(self.owned[slot])] = pid
            self.owned[slot].append(pid)
            self.allocs += 1
        return max(grow, 0)

    # admission vocabulary: a new prompt allocates exactly ceil(len/page)
    alloc = ensure

    def release(self, slot: int) -> int:
        """Retire a slot: return its pages + reservation, zero its table."""
        n = len(self.owned[slot])
        for pid in self.owned[slot]:
            self.free.append(pid)
            self.releases += 1
        self.owned[slot] = []
        self.reserved[slot] = 0
        self.tables[slot, :] = 0
        return n

    # ----------------------------------------------------------- invariants
    def check(self) -> None:
        """Assert the pool invariants (cheap; tests call it every step)."""
        seen = set(self.free)
        assert len(seen) == len(self.free), "double-free in the free list"
        assert 0 not in seen, "null page leaked into the free list"
        for slot, pages in enumerate(self.owned):
            for j, pid in enumerate(pages):
                assert pid not in seen, \
                    f"page {pid} both free and owned by slot {slot}"
                assert self.tables[slot, j] == pid, "table/ownership skew"
                seen.add(pid)
            assert (self.tables[slot, len(pages):] == 0).all(), \
                f"slot {slot}: stale table entries past its allocation"
        assert seen == set(range(1, self.num_pages)), \
            f"page leak: {set(range(1, self.num_pages)) - seen} unaccounted"

    def all_free(self) -> bool:
        return len(self.free) == self.num_pages - 1

    def __repr__(self) -> str:
        used = self.num_pages - 1 - len(self.free)
        return (f"KVPool(pages={self.num_pages}, page_size={self.page_size},"
                f" used={used}, free={len(self.free)},"
                f" allocs={self.allocs}, releases={self.releases})")
