"""Host-side page allocator for the paged KV cache (the pool manager).

The device state (:class:`repro.models.attention.PagedKVCache`) is dumb
storage: a pool of ``[num_pages, page_size, KVH, Dh]`` pages per layer and
per-slot page tables.  THIS class owns the policy: a global free list of
physical pages, per-slot ownership, and the ``[slots, max_pages]`` int32
table mirror the scheduler uploads before every decode segment.

Since the prefix-cache PR the pool is **content-addressed**: pages are
refcounted, and a radix trie over full-page token chunks
(:meth:`admit_prefix` / :meth:`register_prefix`) lets N slots map the SAME
physical pages for a shared prompt prefix — the prefix is prefilled once,
ever.  A slot that must write into a page another reference still needs
(the partial last page of a matched prefix, or an in-page fork point)
gets a private copy first: :meth:`admit_prefix` allocates the
copy-on-write destination and reports the ``(src, dst)`` pair for the
engine's batched device-side page copy.  Retired prompts stay in the trie
(refcount 1, index-only) until capacity pressure evicts them
least-recently-used, leaf-first.

Contract (asserted by :meth:`check`, tested under scheduler churn):

* physical page 0 is the NULL page — never allocated, the landing zone
  for every unallocated table entry's (masked, unread) traffic;
* every non-null page's refcount equals (# slot tables referencing it)
  + (1 if the trie indexes it); a page is free exactly when its
  refcount is 0 (no leak, no double-free);
* shared pages are never written: full-page trie matches are complete
  and immutable, partial matches are COWed before the suffix prefill,
  and decode appends land past the prompt in slot-private pages;
* admission allocates exactly ``ceil(len/page_size) - matched_full``
  fresh pages for the prompt (matched pages cost a refcount bump, zero
  prefill compute) and RESERVES the slot's worst-case growth
  (:meth:`reserve`) so decode-time :meth:`ensure` calls can never
  exhaust the pool mid-run — a request that cannot reserve simply waits
  in the queue (backpressure, not a mid-flight abort).  All admission
  COW happens before the reservation is drawn down, so the accounting
  stays exact;
* decode growth (:meth:`ensure`) adds pages one boundary at a time;
  retirement (:meth:`release`) drops the slot's references — pages the
  trie still indexes are retained for future prefix hits.

Sizing: :func:`recommended_pages` provisions the dense worst case plus
segment-overshoot headroom — safe but savings-free.  Real deployments set
``ServeConfig.pool_pages`` from expected traffic (mean context, not
``max_seq``); the pool then admission-gates when fragmentation would
otherwise overcommit, which is the scheduler's backpressure signal.
Index-only pages count as reclaimable for that gate — they are evicted
on demand, never block an admission.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["KVPool", "PrefixAdmit", "pages_for", "recommended_pages",
           "table_width_for"]


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` tokens: ceil(tokens / page_size)."""
    return -(-tokens // page_size)


def table_width_for(max_seq: int, page_size: int, headroom: int = 0) -> int:
    """Logical pages per slot: ceil((max_seq + headroom) / page_size).

    ``headroom`` covers decode-segment overshoot (power-of-two quantized
    segments may write up to a segment past a request's budget)."""
    return pages_for(max_seq + headroom, page_size)


def recommended_pages(slots: int, max_seq: int, page_size: int,
                      headroom: int = 0) -> int:
    """Worst-case pool size: every slot at max_seq (+headroom), plus the
    null page.  A safe default — pools sized below it are the point."""
    return slots * table_width_for(max_seq, page_size, headroom) + 1


@dataclasses.dataclass(frozen=True)
class PrefixAdmit:
    """Outcome of :meth:`KVPool.admit_prefix` for one admission.

    ``matched_len`` tokens of the prompt are already resident (their K/V
    need no prefill); ``shared_full`` of the slot's pages are full-page
    trie hits (mapped read-only); ``cow`` is the device page copy the
    engine must run before the suffix prefill — ``(src, dst)`` physical
    ids, or None when the match ended exactly on a page boundary."""

    matched_len: int = 0
    shared_full: int = 0
    cow: Optional[Tuple[int, int]] = None


class _Node:
    """One radix-trie node = one FULL page of ``page_size`` tokens.

    Children are keyed by their exact token chunk, so the trie is a
    page-granular radix tree over prompt prefixes; ``stamp`` is the LRU
    clock eviction orders index-only leaves by."""

    __slots__ = ("chunk", "page", "children", "parent", "stamp")

    def __init__(self, chunk: Tuple[int, ...], page: int,
                 parent: Optional["_Node"], stamp: int):
        self.chunk = chunk
        self.page = page
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.stamp = stamp


class KVPool:
    """Global free list + per-slot page tables over a fixed page pool,
    with a refcounted prefix-sharing trie on top."""

    def __init__(self, num_pages: int, page_size: int, slots: int,
                 table_width: int, prefix_cache: bool = True):
        if num_pages < 2:
            raise ValueError(f"pool needs >= 2 pages (got {num_pages}): "
                             "page 0 is reserved as the null page")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.slots = int(slots)
        self.table_width = int(table_width)
        self.prefix_cache = bool(prefix_cache)
        # LIFO free list: recently-released pages are re-used first (their
        # contents are dead anyway and they are likelier cache-warm)
        self.free: Deque[int] = collections.deque(range(1, num_pages))
        # pages withheld from allocation by the chaos harness (simulated
        # external memory pressure): refcount 0 but NOT free — see seize()
        self.seized: List[int] = []
        self.owned: List[List[int]] = [[] for _ in range(slots)]
        self.reserved: List[int] = [0] * slots   # worst-case pages promised
        self.tables = np.zeros((slots, table_width), np.int32)
        self.refcnt: List[int] = [0] * num_pages
        self.allocs = 0          # page references handed to slots (audited)
        self.releases = 0        # page references returned
        # the prefix trie: root children + a page -> node reverse map
        self._root: Dict[Tuple[int, ...], _Node] = {}
        self._node_of: Dict[int, _Node] = {}
        self._clock = itertools.count()
        # prefix-cache telemetry (benchmarks surface these)
        self.prefix_queries = 0
        self.prefix_hit_tokens = 0
        self.prompt_tokens = 0
        self.cow_copies = 0
        self.evictions = 0

    # ------------------------------------------------------------- queries
    def available(self) -> int:
        return len(self.free)

    def evictable(self) -> int:
        """Index-only pages (refcount 1, trie only): reclaimable on
        demand, so they never block an admission."""
        return sum(1 for pid in self._node_of if self.refcnt[pid] == 1)

    def reclaimable(self) -> int:
        """Pages an allocation could draw on: free now, or evictable."""
        return len(self.free) + self.evictable()

    def unpromised(self) -> int:
        """Reclaimable pages not already promised to active slots'
        future growth."""
        outstanding = sum(max(r - len(o), 0)
                          for r, o in zip(self.reserved, self.owned))
        return self.reclaimable() - outstanding

    def can_fit(self, tokens: int, slot: int) -> bool:
        """Would :meth:`ensure` for ``tokens`` total tokens succeed?"""
        need = pages_for(tokens, self.page_size) - len(self.owned[slot])
        return need <= self.reclaimable()

    def can_reserve(self, worst_tokens: int, shared_pages: int = 0) -> bool:
        """Could a NEW slot reserving ``worst_tokens`` of growth be
        admitted without ever failing an :meth:`ensure` later?

        ``shared_pages`` full-page prefix hits (:meth:`match_prefix`)
        are mapped by refcount bump, not drawn from the free list, so
        they tighten the gate — prefix sharing IS extra admission
        capacity, exactly."""
        need = min(pages_for(worst_tokens, self.page_size),
                   self.table_width) - shared_pages
        return need <= self.unpromised()

    def reserve(self, slot: int, worst_tokens: int) -> None:
        """Promise ``worst_tokens`` of total coverage to ``slot`` — gated
        by :meth:`can_reserve` at admission, so every later ensure() up
        to the reservation is guaranteed to find free pages."""
        self.reserved[slot] = min(pages_for(worst_tokens, self.page_size),
                                  self.table_width)

    def slot_pages(self, slot: int) -> int:
        return len(self.owned[slot])

    def table(self) -> np.ndarray:
        """A copy of the [slots, table_width] table for device upload."""
        return self.tables.copy()

    def shared_page_refs(self) -> int:
        """Live slot-table entries served by a page another slot (or the
        same prompt earlier) already owns — physical pages saved NOW."""
        live = [pid for pages in self.owned for pid in pages]
        return len(live) - len(set(live))

    def index_pages(self) -> int:
        """Pages the prefix trie currently indexes."""
        return len(self._node_of)

    def occupancy(self) -> float:
        """Fraction of usable pages not on the free list."""
        usable = self.num_pages - 1
        return (usable - len(self.free)) / max(usable, 1)

    # ----------------------------------------------------- prefix sharing
    def _usable_prefix(self, tokens: Sequence[int]) -> Tuple[int, ...]:
        """Matchable span of a prompt: everything but the last token —
        prefill must process >= 1 real token to produce sampling logits."""
        return tuple(int(t) for t in tokens[:-1])

    def _walk(self, toks: Tuple[int, ...]
              ) -> Tuple[List[_Node], Optional[_Node], int]:
        """Radix walk: longest chain of full-page chunk matches, then the
        best in-page partial (a child whose chunk starts with the
        remaining tokens — the COW fork point)."""
        nodes: List[_Node] = []
        children = self._root
        i = 0
        ps = self.page_size
        while i + ps <= len(toks):
            node = children.get(toks[i:i + ps])
            if node is None:
                break
            nodes.append(node)
            children = node.children
            i += ps
        rem = toks[i:i + ps]
        best, best_j = None, 0
        for node in children.values():
            j = 0
            for a, b in zip(node.chunk, rem):
                if a != b:
                    break
                j += 1
            if j > best_j:
                best, best_j = node, j
        return nodes, best, best_j

    def match_prefix(self, tokens: Sequence[int]) -> Tuple[int, int]:
        """Read-only trie probe: (matched_tokens, full_pages_matched).

        The admission gate uses this BEFORE committing anything —
        ``full_pages_matched`` feeds :meth:`can_reserve`'s
        ``shared_pages`` so backpressure accounts for sharing."""
        if not self.prefix_cache:
            return 0, 0
        nodes, _partial, j = self._walk(self._usable_prefix(tokens))
        return len(nodes) * self.page_size + j, len(nodes)

    def admit_prefix(self, slot: int, tokens: Sequence[int]) -> PrefixAdmit:
        """Map every trie-matched prefix page into ``slot``'s table.

        Full-page matches are mapped read-only (refcount++, zero prefill
        compute).  A partial match — the remaining < page_size tokens are
        a strict prefix of some indexed page's chunk — maps a FRESH page
        instead and reports ``cow=(src, dst)``: the engine copies src's
        contents device-side, then the suffix prefill overwrites from
        ``matched_len`` on.  Must be called on an empty slot, before
        :meth:`reserve`/:meth:`alloc` finish the admission."""
        assert not self.owned[slot], f"slot {slot} admitted while occupied"
        self.prefix_queries += 1
        self.prompt_tokens += len(tokens)
        if not self.prefix_cache:
            return PrefixAdmit()
        nodes, partial, j = self._walk(self._usable_prefix(tokens))
        stamp = next(self._clock)
        for node in nodes:
            pid = node.page
            self.refcnt[pid] += 1
            self.tables[slot, len(self.owned[slot])] = pid
            self.owned[slot].append(pid)
            self.allocs += 1
            node.stamp = stamp
        cow = None
        if partial is not None and j > 0:
            partial.stamp = stamp
            src = partial.page
            dst = self._draw_page(protect={src})
            self.tables[slot, len(self.owned[slot])] = dst
            self.owned[slot].append(dst)
            self.allocs += 1
            cow = (src, dst)
            self.cow_copies += 1
        matched = len(nodes) * self.page_size + j
        self.prefix_hit_tokens += matched
        return PrefixAdmit(matched_len=matched, shared_full=len(nodes),
                           cow=cow)

    def register_prefix(self, slot: int, tokens: Sequence[int]) -> int:
        """Index ``slot``'s now-prefilled FULL prompt pages in the trie.

        Call after the prompt's K/V are resident.  Pages whose chunk is
        already indexed (this slot matched them, or another slot raced
        the registration) just refresh their LRU stamp; fresh full pages
        gain a trie reference (refcount++) and will serve future
        admissions — including after this slot retires.  Returns the
        number of newly indexed pages."""
        if not self.prefix_cache:
            return 0
        toks = tuple(int(t) for t in tokens)
        ps = self.page_size
        n_full = min(len(toks) // ps, len(self.owned[slot]))
        children, parent = self._root, None
        added = 0
        stamp = next(self._clock)
        for pageidx in range(n_full):
            chunk = toks[pageidx * ps:(pageidx + 1) * ps]
            node = children.get(chunk)
            if node is None:
                pid = self.owned[slot][pageidx]
                node = _Node(chunk, pid, parent, stamp)
                children[chunk] = node
                self._node_of[pid] = node
                self.refcnt[pid] += 1
                added += 1
            node.stamp = stamp
            children, parent = node.children, node
        return added

    def _evict_one(self, protect=()) -> bool:
        """Drop the least-recently-used index-only LEAF from the trie,
        returning its page to the free list.  Leaf-first keeps the trie
        consistent (an evicted interior node would orphan descendants
        that remain perfectly servable)."""
        victim = None
        for pid, node in self._node_of.items():
            if (self.refcnt[pid] != 1 or node.children or pid in protect):
                continue
            if victim is None or node.stamp < victim.stamp:
                victim = node
        if victim is None:
            return False
        siblings = (victim.parent.children if victim.parent is not None
                    else self._root)
        del siblings[victim.chunk]
        del self._node_of[victim.page]
        self.refcnt[victim.page] = 0
        self.free.append(victim.page)
        self.evictions += 1
        return True

    def _draw_page(self, protect=()) -> int:
        """Pop a free page, evicting index-only pages if the list is dry."""
        if not self.free and not self._evict_one(protect):
            raise RuntimeError(
                f"KV pool exhausted: 0 free of {self.num_pages - 1} and "
                "nothing evictable (size the pool with "
                "ServeConfig.pool_pages)")
        pid = self.free.pop()
        self.refcnt[pid] = 1
        return pid

    def clear_index(self) -> int:
        """Drop the whole prefix trie; index-only pages return to the
        free list.  Returns the number of pages freed."""
        freed = 0
        for pid in list(self._node_of):
            self.refcnt[pid] -= 1
            if self.refcnt[pid] == 0:
                self.free.append(pid)
                freed += 1
        self._node_of.clear()
        self._root.clear()
        return freed

    # ------------------------------------------------- chaos: seized pages
    def seize(self, n: int) -> int:
        """Withhold up to ``n`` FREE pages from allocation (the chaos
        harness's simulated external memory pressure).  Seized pages stay
        refcount 0 but leave the free list, so every admission gate and
        ensure() sees a genuinely smaller pool; :meth:`check` accounts
        for them.  Returns the number actually seized."""
        taken = 0
        while taken < n and self.free:
            self.seized.append(self.free.pop())
            taken += 1
        return taken

    def unseize(self) -> int:
        """Return every seized page to the free list (pressure relief)."""
        n = len(self.seized)
        self.free.extend(self.seized)
        self.seized.clear()
        return n

    # -------------------------------------------- snapshot: index transfer
    def export_index(self) -> List[Dict]:
        """Serialize the prefix trie for a serving snapshot: one dict per
        node — physical page id, its full-page token chunk, and the
        parent's page id (None at the root) — in parent-before-child
        order, so :meth:`adopt_index` can rebuild linkage in one pass."""
        out: List[Dict] = []
        stack = [(node, None) for node in self._root.values()]
        while stack:
            node, parent_page = stack.pop()
            out.append({"page": int(node.page),
                        "chunk": [int(t) for t in node.chunk],
                        "parent": parent_page})
            stack.extend((c, int(node.page))
                         for c in node.children.values())
        return out

    def adopt_index(self, nodes: Sequence[Dict]) -> int:
        """Rebuild a previously exported trie into THIS (empty) pool.

        The restore path: page ids in ``nodes`` refer to physical pages
        of a same-sized pool, so each adopted page leaves the free list
        and gains the trie's refcount.  The caller is responsible for
        writing the page *contents* back into the device state.  Returns
        the number of pages adopted."""
        assert all(not o for o in self.owned) and not self._node_of, \
            "adopt_index needs an empty pool"
        if not self.prefix_cache or not nodes:
            return 0
        adopt = {int(n["page"]) for n in nodes}
        assert all(0 < p < self.num_pages for p in adopt), \
            f"snapshot page ids out of range for a {self.num_pages}-page pool"
        self.free = collections.deque(p for p in self.free
                                      if p not in adopt)
        stamp = next(self._clock)
        for nd in nodes:
            pid = int(nd["page"])
            chunk = tuple(int(t) for t in nd["chunk"])
            parent = (self._node_of[int(nd["parent"])]
                      if nd["parent"] is not None else None)
            node = _Node(chunk, pid, parent, stamp)
            siblings = parent.children if parent is not None else self._root
            siblings[chunk] = node
            self._node_of[pid] = node
            self.refcnt[pid] = 1
        return len(adopt)

    # ----------------------------------------------------------- lifecycle
    def ensure(self, slot: int, tokens: int) -> int:
        """Grow slot ``slot`` to cover ``tokens`` total tokens; returns the
        number of pages newly allocated.  Raises on pool exhaustion or
        table overflow — the scheduler admission-gates so decode-time
        growth never fails in a correctly-sized deployment."""
        need = pages_for(tokens, self.page_size)
        if need > self.table_width:
            raise ValueError(
                f"slot {slot}: {tokens} tokens need {need} pages "
                f"> table_width {self.table_width}")
        grow = need - len(self.owned[slot])
        while grow > len(self.free) and self._evict_one():
            pass
        if grow > len(self.free):
            raise RuntimeError(
                f"KV pool exhausted: slot {slot} needs {grow} more pages, "
                f"{len(self.free)} free of {self.num_pages - 1} "
                "(size the pool with ServeConfig.pool_pages)")
        for _ in range(max(grow, 0)):
            pid = self.free.pop()
            self.refcnt[pid] = 1
            self.tables[slot, len(self.owned[slot])] = pid
            self.owned[slot].append(pid)
            self.allocs += 1
        return max(grow, 0)

    # admission vocabulary: a new prompt allocates exactly ceil(len/page)
    alloc = ensure

    def release(self, slot: int) -> int:
        """Retire a slot: drop its page references + reservation, zero its
        table.  Pages the trie still indexes are RETAINED for future
        prefix hits (refcount stays >= 1); everything else is freed."""
        n = len(self.owned[slot])
        for pid in self.owned[slot]:
            self.refcnt[pid] -= 1
            self.releases += 1
            if self.refcnt[pid] == 0:
                self.free.append(pid)
        self.owned[slot] = []
        self.reserved[slot] = 0
        self.tables[slot, :] = 0
        return n

    # ----------------------------------------------------------- invariants
    def check(self) -> None:
        """Assert the pool invariants (cheap; tests call it every step)."""
        free_set = set(self.free)
        assert len(free_set) == len(self.free), "double-free in the free list"
        assert 0 not in free_set, "null page leaked into the free list"
        seized_set = set(self.seized)
        assert len(seized_set) == len(self.seized), "page seized twice"
        assert not (seized_set & free_set), "page both seized and free"
        assert 0 not in seized_set, "null page seized"
        for pid in seized_set:
            assert self.refcnt[pid] == 0, \
                f"seized page {pid} has refcount {self.refcnt[pid]}"
        slot_refs: collections.Counter = collections.Counter()
        for slot, pages in enumerate(self.owned):
            assert len(pages) == len(set(pages)), \
                f"slot {slot} maps a page twice"
            for j, pid in enumerate(pages):
                assert pid != 0, f"slot {slot} owns the null page"
                assert pid not in free_set, \
                    f"page {pid} both free and owned by slot {slot}"
                assert self.tables[slot, j] == pid, "table/ownership skew"
                slot_refs[pid] += 1
            assert (self.tables[slot, len(pages):] == 0).all(), \
                f"slot {slot}: stale table entries past its allocation"
        for pid in range(1, self.num_pages):
            want = slot_refs[pid] + (1 if pid in self._node_of else 0)
            assert self.refcnt[pid] == want, \
                (f"page {pid}: refcount {self.refcnt[pid]} != "
                 f"{slot_refs[pid]} slot refs + "
                 f"{int(pid in self._node_of)} index refs")
            assert (self.refcnt[pid] == 0) == (pid in free_set
                                               or pid in seized_set), \
                f"page {pid}: refcount {self.refcnt[pid]} vs free-list skew"
        assert self.refcnt[0] == 0, "null page refcounted"
        # trie structure: reverse map exact, linkage consistent, and the
        # sharing closure (a slot maps a node only with all its ancestors,
        # so an index-only node never has a slot-referenced descendant)
        def walk(children, parent):
            for chunk, node in children.items():
                assert node.chunk == chunk and node.parent is parent
                assert self._node_of.get(node.page) is node, \
                    f"trie page {node.page} reverse-map skew"
                assert len(chunk) == self.page_size
                if self.refcnt[node.page] == 1:
                    bad = [c.page for c in node.children.values()
                           if self.refcnt[c.page] > 1]
                    assert not bad, \
                        (f"index-only page {node.page} has slot-referenced "
                         f"children {bad}")
                walk(node.children, node)
        walk(self._root, None)
        reachable = sum(1 for _ in self._iter_nodes())
        assert reachable == len(self._node_of), "orphaned trie nodes"

    def _iter_nodes(self):
        stack = list(self._root.values())
        while stack:
            node = stack.pop()
            yield node
            stack.extend(node.children.values())

    def all_free(self) -> bool:
        return len(self.free) == self.num_pages - 1

    def __repr__(self) -> str:
        used = self.num_pages - 1 - len(self.free)
        return (f"KVPool(pages={self.num_pages}, page_size={self.page_size},"
                f" used={used}, free={len(self.free)},"
                f" indexed={len(self._node_of)},"
                f" allocs={self.allocs}, releases={self.releases})")
