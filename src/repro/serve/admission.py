"""Bounded admission for the serving request plane.

Before this module the scheduler's queue was an unbounded FIFO deque:
overload deferred silently and forever, a large request stuck behind
``can_reserve`` could be starved by an endless stream of smaller later
arrivals, and a rejected caller had no signal about when (or whether) to
retry.  :class:`AdmissionQueue` fixes all three:

* **Priority classes** — requests carry an integer priority (lower is
  more urgent; 0 = interactive, 1 = default, 2 = batch/background).
  Dequeue order is (priority, arrival), so within a class the queue is
  strictly FIFO — the order the scheduler's admission log asserts.
* **Bounded depth + load shedding** — ``max_queue`` caps the queue.  At
  capacity, ``shed_policy`` decides in O(1): ``"reject-new"`` refuses
  the arriving request; ``"shed-lowest"`` evicts the *newest request of
  the strictly worst priority class* (least sunk cost, least urgent) to
  make room for a more urgent arrival — an arrival no more urgent than
  the worst resident class is itself refused.  Either way the refused
  party gets a structured :class:`Rejection` (retryable, with a
  suggested backoff derived from observed service rate) wrapped in
  :class:`AdmissionRejected` — never an unbounded defer.
* **Bounded bypass** — when the head-of-line request cannot reserve its
  worst-case pages, the scheduler may admit smaller later requests past
  it, but only ``max_bypass`` times per head: after that the queue
  BLOCKS until the head fits (pages drain toward it), so a large
  request is delayed at most K admissions, never starved.
* **Drain** — :meth:`close` stops admission (rejections carry
  ``reason="draining"``, not retryable here — the process is going
  away); already-queued work is unaffected.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Deque, Dict, Iterator, List, Optional

__all__ = ["AdmissionQueue", "AdmissionRejected", "Rejection",
           "SHED_POLICIES"]

SHED_POLICIES = ("reject-new", "shed-lowest")


@dataclasses.dataclass(frozen=True)
class Rejection:
    """Structured admission refusal — the caller can act on it.

    ``retryable`` distinguishes transient overload (back off and retry
    after ``retry_after_s``) from terminal refusals (the scheduler is
    draining); ``queue_depth`` is the depth observed at refusal time so
    clients can do their own load-aware routing."""

    rid: int
    reason: str                  # "queue_full" | "shed" | "draining"
    retryable: bool = True
    retry_after_s: float = 0.1
    priority: int = 1
    queue_depth: int = 0


class AdmissionRejected(RuntimeError):
    """Raised by submit/push when a request is refused admission."""

    def __init__(self, rejection: Rejection):
        self.rejection = rejection
        hint = (f"; retry after {rejection.retry_after_s:.2f}s"
                if rejection.retryable else "; not retryable")
        super().__init__(
            f"request {rejection.rid} rejected ({rejection.reason}, "
            f"depth={rejection.queue_depth}){hint}")


class AdmissionQueue:
    """Priority-FIFO admission queue with a bounded depth and bounded
    head-of-line bypass.

    All mutating operations are O(number of priority classes) or better
    — the rejection path never scans the queue, which is what makes the
    overload behavior O(1) per arrival."""

    def __init__(self, max_queue: Optional[int] = None,
                 shed_policy: str = "reject-new", max_bypass: int = 4):
        if shed_policy not in SHED_POLICIES:
            raise ValueError(f"unknown shed_policy {shed_policy!r}; "
                             f"choose from {SHED_POLICIES}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_queue = max_queue
        self.shed_policy = shed_policy
        self.max_bypass = int(max_bypass)
        self.closed = False
        self._classes: Dict[int, Deque] = {}
        self._seq = itertools.count()
        self._order: Dict[int, int] = {}     # rid -> arrival seq
        # bounded-bypass bookkeeping: how many times the CURRENT head has
        # been bypassed by later arrivals (reset whenever the head changes)
        self._bypass_rid: Optional[int] = None
        self._bypass_count = 0
        # EMA of per-request service time, fed by the scheduler at retire
        # time; the backoff hint scales with it and the observed depth
        self._service_ema_s: Optional[float] = None

    # -------------------------------------------------------------- queries
    def __len__(self) -> int:
        return sum(len(d) for d in self._classes.values())

    def __bool__(self) -> bool:
        return any(self._classes.values())

    def ordered(self) -> Iterator:
        """Requests in dequeue order: (priority, arrival)."""
        for prio in sorted(self._classes):
            yield from self._classes[prio]

    def head(self):
        """The request the queue would serve next, or None."""
        for prio in sorted(self._classes):
            if self._classes[prio]:
                return self._classes[prio][0]
        return None

    def retry_after_s(self) -> float:
        """Suggested backoff: queue depth x observed service time (with a
        floor so a cold queue still suggests a real pause)."""
        per = self._service_ema_s if self._service_ema_s else 0.05
        return max(0.05, per * (len(self) + 1))

    def note_service_time(self, seconds: float) -> None:
        """Feed one completed request's wall time into the backoff EMA."""
        if self._service_ema_s is None:
            self._service_ema_s = float(seconds)
        else:
            self._service_ema_s += 0.2 * (float(seconds)
                                          - self._service_ema_s)

    # ------------------------------------------------------------ mutation
    def _reject(self, req, reason: str, retryable: bool = True) -> None:
        raise AdmissionRejected(Rejection(
            rid=req.rid, reason=reason, retryable=retryable,
            retry_after_s=self.retry_after_s() if retryable else 0.0,
            priority=getattr(req, "priority", 1), queue_depth=len(self)))

    def _enqueue(self, req, seq: int) -> None:
        prio = int(getattr(req, "priority", 1))
        self._classes.setdefault(prio, collections.deque()).append(req)
        self._order[req.rid] = seq

    def push(self, req):
        """Admit ``req`` (or shed/refuse in O(1)).

        Returns the shed victim (a request previously queued, now
        evicted under ``shed-lowest``) or None; raises
        :class:`AdmissionRejected` when ``req`` itself is refused."""
        if self.closed:
            self._reject(req, "draining", retryable=False)
        victim = None
        if self.max_queue is not None and len(self) >= self.max_queue:
            if self.shed_policy == "reject-new":
                self._reject(req, "queue_full")
            worst = max((p for p, d in self._classes.items() if d),
                        default=None)
            if worst is None or worst <= int(getattr(req, "priority", 1)):
                # nothing strictly less urgent to shed -> refuse arrival
                self._reject(req, "queue_full")
            victim = self._classes[worst].pop()     # newest of worst class
            self._order.pop(victim.rid, None)
            if self._bypass_rid == victim.rid:
                self._bypass_rid, self._bypass_count = None, 0
        self._enqueue(req, next(self._seq))
        return victim

    def push_front(self, req) -> None:
        """Re-queue ahead of every same-priority request (resume/restore
        path: the request was already admitted once).  Never bounded —
        refusing previously-admitted work would lose it."""
        prio = int(getattr(req, "priority", 1))
        self._classes.setdefault(prio, collections.deque()).appendleft(req)
        # arrival seq below every existing one of this class
        floor = min((self._order[r.rid] for r in self._classes[prio]
                     if r.rid in self._order), default=0)
        self._order[req.rid] = floor - 1

    def remove(self, req) -> bool:
        """Drop a queued request (cancel/expiry sweep).  True if found."""
        for d in self._classes.values():
            try:
                d.remove(req)
            except ValueError:
                continue
            self._order.pop(req.rid, None)
            if self._bypass_rid == req.rid:
                self._bypass_rid, self._bypass_count = None, 0
            return True
        return False

    def close(self) -> None:
        """Stop admission (drain): future pushes are refused."""
        self.closed = True

    # ------------------------------------------------- bounded head bypass
    def bypasses(self, head) -> int:
        """Times the current head has been bypassed (0 on head change)."""
        if self._bypass_rid != head.rid:
            return 0
        return self._bypass_count

    def note_bypass(self, head) -> int:
        """Record one bypass of ``head`` by a later arrival."""
        if self._bypass_rid != head.rid:
            self._bypass_rid, self._bypass_count = head.rid, 0
        self._bypass_count += 1
        return self._bypass_count
